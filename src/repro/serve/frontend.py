"""Overload-robust async admission front end: continuous batching.

The engines below this layer are *pull* interfaces: a caller hands
``ServeEngine``/``ResilientEngine`` a batch and blocks for the answer.
That is the wrong shape for open-loop traffic — clients arrive when they
arrive, and when arrivals exceed capacity something must give.  Without an
admission layer the "something" is an unbounded queue (latency grows
without bound) or a silent drop (the worst failure mode a serving system
has).  ``AsyncFrontend`` makes overload a *typed, bounded, observable*
state instead:

**Continuous batching.**  ``submit()`` returns a future immediately;
dispatcher workers continuously drain the admission queue, coalescing
ragged requests for the same estimator into one fused dispatch against
the existing shape-bucket ladder (``query_many`` on a plain engine,
``coalesce``/``split`` around a ``ResilientEngine.query``).  Batches form
from whatever is queued *now* — a request never waits for a fixed-size
batch to fill, and a burst never dispatches one-by-one.

**Bounded admission queue + state machine.**  The queue holds at most
``max_queue`` requests and admission follows an explicit state machine
driven by queue depth (with hysteresis so the state does not flap):

    accepting ⇄ backpressure ⇄ shedding → draining

  * ``accepting`` — depth below the backpressure watermark: admit freely.
  * ``backpressure`` — depth past ``backpressure_frac``: admission costs
    a token (see below); callers without one get a typed ``Overloaded``.
  * ``shedding`` — depth past ``shed_frac``: the token rate has already
    been collapsed by AIMD breaches, most arrivals shed, and queued work
    is browned out (below).
  * ``draining`` — terminal (``drain()``/``close()``): nothing new is
    admitted, everything already queued resolves.

**Token bucket + AIMD.**  Under pressure admission spends tokens from a
bucket whose refill rate is adapted AIMD-style by the same signals the
obs layer exports: each batch that completes inside the p99 SLO with a
shallow queue bumps the rate additively; a queue-full rejection, a shed
transition, or a dispatch past the SLO cuts it multiplicatively.  The
admitted rate therefore tracks measured capacity instead of a static
config guess.

**EDF + deadlines end to end.**  The queue is a deadline heap: workers
always pop the earliest-deadline request first (EDF — the policy that
meets every deadline whenever any policy can).  A request that expires
while queued resolves with typed ``DeadlineExceeded``; admitted requests
carry their absolute deadline into the engine (``deadline_s`` on the
plain engine, ``deadline_ms`` on the resilient one), so a late answer is
also typed, never silently stale.  **Every** submitted request resolves
as an answer, ``Overloaded``, ``DeadlineExceeded``, or a certified
``Degraded`` — the zero-silent-drop contract the overload soak enforces.

**Brownout ladder.**  As pressure rises the frontend sheds *work* before
it sheds *requests*: at ``backpressure`` queued requests without an
explicit tier are served one precision rung down the planner ladder
(``TIER_ORDER``), at ``shedding`` at the cheapest rung — and, fronting a
``ResilientEngine``, shedding also opts into PR 8's certified degraded
answers, so even a partially-dead backend keeps answering with an error
bound attached rather than rejecting.

Chaos: the admit path carries the ``serve.admit`` injection point —
``admit_stall`` sleeps the admitting caller (a stalled accept loop),
``client_burst`` enqueues ``burst_factor`` synthetic duplicates of the
arriving request (a deterministic traffic surge that exercises the whole
backpressure → shed arc).  Everything is instrumented: queue-depth and
admitted-rate gauges, admit/reject/brownout/expired counters, a
time-in-queue histogram, and ``frontend.batch`` spans per dispatch.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import fault_injection, obs
from repro.fault_injection import InjectedFailure
from repro.plan.planner import TIER_ORDER
from repro.serve.api import RFF_TIER, Answer, QueryRequest, warn_legacy
from repro.serve.batching import coalesce, split
from repro.serve.engine import ServeEngine
from repro.serve.errors import DeadlineExceeded, Overloaded, ServeError
from repro.serve.resilience import ResilientEngine

ACCEPTING = "accepting"
BACKPRESSURE = "backpressure"
SHEDDING = "shedding"
DRAINING = "draining"

#: Queue-pressure level per state (indexes the brownout ladder).
_LEVEL = {ACCEPTING: 0, BACKPRESSURE: 1, SHEDDING: 2, DRAINING: 2}


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Admission policy: queue bounds, watermarks, rates, brownout."""

    max_queue: int = 128          # hard bound on queued requests
    backpressure_frac: float = 0.375   # depth fraction entering backpressure
    shed_frac: float = 0.75            # depth fraction entering shedding
    hysteresis: float = 0.5       # exit watermark = enter watermark × this
    workers: int = 1              # dispatcher threads (0 = manual pump())
    batch_wait_ms: float = 2.0    # coalescing wait when the queue is shallow
    default_deadline_ms: float = 1000.0
    max_retries: int = 2          # injected-failure requeues per request
    # token bucket + AIMD (admission is token-gated under pressure)
    rate: float = 256.0           # initial admitted requests/sec
    burst: float = 64.0           # bucket capacity (tokens)
    min_rate: float = 4.0
    max_rate: float = 1e5
    aimd_increase: float = 8.0    # +req/s per healthy batch completion
    aimd_decrease: float = 0.5    # ×rate per breach signal
    p99_slo_ms: float = 250.0     # dispatch-latency SLO feeding AIMD
    # brownout: pressure level → tier override for requests with no
    # explicit precision (None = serve the engine-config tier).  Any
    # exact ladder rung (TIER_ORDER) or "rff" is valid — an RFF rung
    # sheds *work* hardest of all (one train-independent feature GEMM,
    # band still attached), but only on engines whose method/backend the
    # RFF tier supports, so it is opt-in rather than the default.
    brownout_tiers: Tuple[Optional[str], ...] = (None, None, TIER_ORDER[-1])
    brownout_degraded: bool = True   # shedding + resilient → opt into
                                     # certified degraded answers

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if not (0.0 < self.backpressure_frac <= self.shed_frac <= 1.0):
            raise ValueError(
                f"need 0 < backpressure_frac <= shed_frac <= 1, got "
                f"{self.backpressure_frac}/{self.shed_frac}")
        if not (0.0 < self.hysteresis <= 1.0):
            raise ValueError("hysteresis must be in (0, 1]")
        if self.workers < 0:
            raise ValueError("workers must be >= 0")
        for name in ("default_deadline_ms", "rate", "burst", "min_rate",
                     "max_rate", "aimd_increase", "p99_slo_ms"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be > 0")
        if not (0.0 < self.aimd_decrease < 1.0):
            raise ValueError("aimd_decrease must be in (0, 1)")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if len(self.brownout_tiers) != 3:
            raise ValueError("brownout_tiers maps the 3 pressure levels")
        for t in self.brownout_tiers:
            if t is not None and t not in TIER_ORDER + (RFF_TIER,):
                raise ValueError(f"unknown brownout tier {t!r}")


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s up to ``capacity``."""

    def __init__(self, rate: float, capacity: float,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = float(rate)
        self.capacity = float(capacity)
        self.tokens = float(capacity)
        self.clock = clock
        self._last = clock()
        self._lock = threading.Lock()

    def set_rate(self, rate: float) -> None:
        with self._lock:
            self._refill()
            self.rate = float(rate)

    def take(self, k: float = 1.0) -> bool:
        with self._lock:
            self._refill()
            if self.tokens >= k:
                self.tokens -= k
                return True
            return False

    def _refill(self) -> None:
        now = self.clock()
        self.tokens = min(self.capacity,
                          self.tokens + (now - self._last) * self.rate)
        self._last = now


class AimdController:
    """Additive-increase / multiplicative-decrease on the admitted rate.

    The TCP congestion-control shape applied to admission: healthy
    completions (dispatch inside the SLO, shallow queue) add
    ``increase`` req/s; any breach (queue full, shed transition, SLO
    miss) multiplies by ``decrease``.  The rate is clamped to
    [min_rate, max_rate] and drives the token bucket's refill.
    """

    def __init__(self, bucket: TokenBucket, *, increase: float,
                 decrease: float, min_rate: float, max_rate: float):
        self.bucket = bucket
        self.increase = increase
        self.decrease = decrease
        self.min_rate = min_rate
        self.max_rate = max_rate
        self.rate = bucket.rate
        self._lock = threading.Lock()

    def on_healthy(self) -> None:
        with self._lock:
            self.rate = min(self.max_rate, self.rate + self.increase)
            self.bucket.set_rate(self.rate)
        obs.gauge("frontend.admit_rate",
                  "AIMD-controlled admitted requests/sec").set(self.rate)

    def on_breach(self, reason: str) -> None:
        with self._lock:
            self.rate = max(self.min_rate, self.rate * self.decrease)
            self.bucket.set_rate(self.rate)
        obs.counter("frontend.aimd_breaches",
                    "multiplicative admission-rate cuts",
                    labels={"reason": reason}).inc()
        obs.gauge("frontend.admit_rate",
                  "AIMD-controlled admitted requests/sec").set(self.rate)


class AdmissionStateMachine:
    """accepting ⇄ backpressure ⇄ shedding → draining, with hysteresis.

    Depth watermarks enter a state at ``frac × max_queue`` and exit it at
    ``hysteresis × enter`` — a queue oscillating around one watermark
    does not flap the state (and with it the brownout tier) per request.
    ``draining`` is terminal and reachable only via :meth:`drain`.
    """

    def __init__(self, max_queue: int, backpressure_frac: float,
                 shed_frac: float, hysteresis: float):
        self.bp_enter = max(1, int(round(backpressure_frac * max_queue)))
        self.shed_enter = max(self.bp_enter,
                              int(round(shed_frac * max_queue)))
        self.bp_exit = int(self.bp_enter * hysteresis)
        self.shed_exit = max(self.bp_enter,
                             int(self.shed_enter * hysteresis))
        self.state = ACCEPTING
        self.transitions: List[Tuple[str, str]] = []

    @property
    def level(self) -> int:
        return _LEVEL[self.state]

    def observe(self, depth: int) -> str:
        """Fold the current queue depth into the state; returns it."""
        s = self.state
        if s == DRAINING:
            return s
        if depth >= self.shed_enter or (s == SHEDDING
                                        and depth > self.shed_exit):
            nxt = SHEDDING
        elif depth >= self.bp_enter or (s != ACCEPTING
                                        and depth > self.bp_exit):
            nxt = BACKPRESSURE
        else:
            nxt = ACCEPTING
        if nxt != s:
            self._transition(nxt)
        return nxt

    def drain(self) -> None:
        if self.state != DRAINING:
            self._transition(DRAINING)

    def _transition(self, to: str) -> None:
        self.transitions.append((self.state, to))
        self.state = to
        obs.counter("frontend.state_transitions",
                    "admission state machine transitions",
                    labels={"to": to}).inc()


# The frontend resolves futures to the same typed Answer the engines
# return (serve/api.py), with the admission provenance fields
# (state/queued_ms/browned/batch_requests) filled in.  The old name stays
# as an alias for callers that imported it.
FrontendAnswer = Answer


@dataclasses.dataclass
class _Pending:
    """One admitted request waiting in the EDF heap."""

    deadline: float                  # absolute monotonic seconds
    seq: int
    key: str
    y: jnp.ndarray
    rows: int
    precision: Optional[str]         # explicit per-request tier (wins
                                     # over the brownout ladder)
    future: Future
    enq: float
    retries: int = 0
    synthetic: bool = False          # chaos client_burst duplicate
    accuracy_target: Optional[float] = None   # cascade gate, per request
    allow_degraded: Optional[bool] = None     # resilient opt-in override

    def entry(self):
        return (self.deadline, self.seq, self)


class AsyncFrontend:
    """Admission front end over a ``ServeEngine`` or ``ResilientEngine``.

    ``submit()`` admits (or sheds, typed) and returns a
    ``concurrent.futures.Future`` resolving to a :class:`FrontendAnswer`;
    ``query()`` is the blocking convenience; ``aquery()`` awaits the same
    future from asyncio.  ``workers=0`` disables the dispatcher threads —
    tests (and anyone embedding the frontend in their own loop) call
    :meth:`pump` to run batches synchronously and deterministically.
    """

    def __init__(self, engine, config: FrontendConfig | None = None, *,
                 clock: Callable[[], float] = time.monotonic):
        cfg = config or FrontendConfig()
        self.engine = engine
        self.config = cfg
        self._resilient = isinstance(engine, ResilientEngine)
        if not self._resilient and not isinstance(engine, ServeEngine):
            raise TypeError(
                f"AsyncFrontend fronts ServeEngine or ResilientEngine, "
                f"got {type(engine).__name__}")
        if cfg.workers > 1 and not self._resilient:
            # a plain ServeEngine's bucket cache is not reentrant; the
            # resilient layer serializes per replica internally
            raise ValueError(
                "workers > 1 requires a ResilientEngine backend (the "
                "plain ServeEngine is single-dispatch)")
        self._clock = clock
        self.sm = AdmissionStateMachine(
            cfg.max_queue, cfg.backpressure_frac, cfg.shed_frac,
            cfg.hysteresis)
        self.bucket = TokenBucket(cfg.rate, cfg.burst, clock)
        self.aimd = AimdController(
            self.bucket, increase=cfg.aimd_increase,
            decrease=cfg.aimd_decrease, min_rate=cfg.min_rate,
            max_rate=cfg.max_rate)
        self._heap: List[tuple] = []
        self._seq = 0
        self._inflight = 0
        self._stop = False
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self.stats = {k: 0 for k in (
            "submitted", "admitted", "answered", "degraded", "browned",
            "expired", "late", "retries", "batches", "synthetic",
            "rejected", "errored")}
        self._rejected_by: dict = {}
        self._queue_wait = obs.histogram(
            "frontend.queue_wait_s", "admit → dispatch seconds in queue",
            lo=1e-5, hi=1e3)
        self._workers = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"frontend-{i}")
            for i in range(cfg.workers)
        ]
        for t in self._workers:
            t.start()

    # -- admission --------------------------------------------------------

    def submit(self, request, y=None, *,
               deadline_s: Optional[float] = None,
               precision: Optional[str] = None) -> Future:
        """Admit one request; returns its future or raises ``Overloaded``.

        Typed API: pass a :class:`~repro.serve.api.QueryRequest` — its
        ``deadline_s`` is *relative* seconds from now (default
        ``config.default_deadline_ms``), its ``accuracy_target`` rides
        into the engine's cascade, its ``precision`` pin wins over the
        brownout ladder, and its ``allow_degraded`` overrides the
        resilient engine's default.  The future resolves to an
        :class:`~repro.serve.api.Answer`.

        Legacy API (deprecated): ``submit(key, y, deadline_s=,
        precision=)``.

        The admit decision is synchronous: a shed request fails HERE,
        typed, with the shed reason — it never enters the queue, and
        nothing about it is silent.
        """
        if isinstance(request, QueryRequest):
            if y is not None or precision is not None \
                    or deadline_s is not None:
                raise ValueError(
                    "pass either a QueryRequest or the legacy "
                    "(key, y, ...) arguments, not both")
            req = request
        else:
            warn_legacy("AsyncFrontend.submit(key, y, ...)",
                        "AsyncFrontend.submit(QueryRequest(...))")
            req = QueryRequest(key=request, points=y, precision=precision,
                               deadline_s=deadline_s)
        self.stats["submitted"] += 1
        # chaos: a stalled admission thread blocks its caller right here,
        # before any admission decision — arrivals back up behind it.
        # Fronting a plain engine nothing else advances the injector's
        # request clock, so scheduled ChaosEvent windows are indexed off
        # arrivals; the resilient engine keeps its own per-query clock.
        inj = fault_injection.active()
        if inj is not None and not self._resilient:
            inj.begin_request()
        fault_injection.fire("serve.admit", key=req.key)
        nburst = fault_injection.burst("serve.admit")
        pts = np.atleast_2d(np.asarray(req.points, np.float32))
        if nburst:
            self._inject_burst(req.key, pts, nburst)
        rel = (self.config.default_deadline_ms / 1e3
               if req.deadline_s is None else req.deadline_s)
        return self._admit(req.key, pts, rel, req.precision,
                           synthetic=False,
                           accuracy_target=req.accuracy_target,
                           allow_degraded=req.allow_degraded)

    def query(self, request, y=None, *,
              deadline_s: Optional[float] = None,
              precision: Optional[str] = None) -> Answer:
        """Blocking convenience: ``submit`` + wait (typed errors raise)."""
        return self.submit(request, y, deadline_s=deadline_s,
                           precision=precision).result()

    async def aquery(self, request, y=None, *,
                     deadline_s: Optional[float] = None,
                     precision: Optional[str] = None) -> Answer:
        """Awaitable ``query`` for asyncio callers (one shared wrapper:
        the future the dispatcher resolves IS the awaited one)."""
        import asyncio

        return await asyncio.wrap_future(
            self.submit(request, y, deadline_s=deadline_s,
                        precision=precision))

    def _admit(self, key: str, y, rel_deadline: float,
               precision: Optional[str], *, synthetic: bool,
               accuracy_target: Optional[float] = None,
               allow_degraded: Optional[bool] = None) -> Future:
        cfg = self.config
        fut: Future = Future()
        now = self._clock()
        with self._cv:
            depth = len(self._heap)
            state = self.sm.observe(depth)
            if self._stop or state == DRAINING:
                return self._reject(fut, "draining", synthetic,
                                    f"frontend draining; request for "
                                    f"{key!r} not admitted")
            if depth >= cfg.max_queue:
                self.aimd.on_breach("queue_full")
                return self._reject(
                    fut, "queue_full", synthetic,
                    f"admission queue full ({depth}/{cfg.max_queue})")
            if state in (BACKPRESSURE, SHEDDING) and not self.bucket.take():
                return self._reject(
                    fut, state, synthetic,
                    f"admission rate exhausted under {state} "
                    f"(AIMD rate {self.aimd.rate:.0f} req/s, "
                    f"queue {depth}/{cfg.max_queue})")
            self._seq += 1
            p = _Pending(deadline=now + rel_deadline, seq=self._seq,
                         key=key, y=y, rows=int(y.shape[0]),
                         precision=precision, future=fut, enq=now,
                         synthetic=synthetic,
                         accuracy_target=accuracy_target,
                         allow_degraded=allow_degraded)
            heapq.heappush(self._heap, p.entry())
            self.stats["admitted"] += 1
            if synthetic:
                self.stats["synthetic"] += 1
            obs.counter("frontend.admitted", "requests admitted to the "
                        "queue").inc()
            obs.gauge("frontend.queue_depth",
                      "admission queue depth").set(len(self._heap))
            self._cv.notify()
        return fut

    def _reject(self, fut: Future, reason: str, synthetic: bool,
                msg: str) -> Future:
        """Typed shed: count it, resolve/raise ``Overloaded`` — a real
        caller raises synchronously, a synthetic burst request resolves
        its (unobserved) future so even chaos traffic is never silent."""
        self.stats["rejected"] += 1
        self._rejected_by[reason] = self._rejected_by.get(reason, 0) + 1
        obs.counter("frontend.rejected", "requests shed at admission",
                    labels={"reason": reason}).inc()
        err = Overloaded(msg, reason=reason)
        if synthetic:
            self.stats["synthetic"] += 1
            fut.set_exception(err)
            return fut
        raise err

    def _inject_burst(self, key: str, y, k: int) -> None:
        """chaos ``client_burst``: k synthetic duplicates of this arrival
        go through the SAME admission path (their shed/brownout outcomes
        are tracked under ``stats['synthetic']``; nobody awaits them)."""
        rel = self.config.default_deadline_ms / 1e3
        for _ in range(k):
            fut = self._admit(key, y, rel, None, synthetic=True)
            # exceptions on unobserved futures are swallowed deliberately
            fut.add_done_callback(lambda f: f.exception())

    # -- dispatch ---------------------------------------------------------

    def pump(self, max_batches: int = 1 << 30) -> int:
        """Dispatch up to ``max_batches`` coalesced batches synchronously
        (the ``workers=0`` mode; also safe alongside live workers)."""
        done = 0
        while done < max_batches:
            batch = self._next_batch(block=False)
            if not batch:
                break
            self._dispatch(batch)
            done += 1
        return done

    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch(block=True)
            if batch is None:          # stop signal
                return
            if batch:
                self._dispatch(batch)

    def _next_batch(self, block: bool):
        """Pop the EDF-earliest request, expire stale ones (typed), then
        coalesce compatible queued requests into one dispatch.

        Returns a list of ``_Pending`` (possibly empty when non-blocking
        and the queue is idle), or None when the frontend is stopping.
        """
        cfg = self.config
        with self._cv:
            while True:
                if self._stop and not self._heap:
                    return None if block else []
                if self._heap:
                    break
                if not block:
                    return []
                self._cv.wait(timeout=0.1)
            first = self._pop_live()
            if first is None:
                return []
            batch = [first]
            # claim inflight BEFORE the straggler wait below: cv.wait
            # releases the lock with the head request already popped, and
            # without the claim a concurrent drain() would observe
            # heap-empty + inflight-zero and return while this request
            # is still unserved in the worker's hands
            self._inflight += 1
            self._coalesce_into(batch)
            # shallow queue: give stragglers one short window to fuse,
            # bounded by the head request's deadline slack
            slack = first.deadline - self._clock()
            wait = min(cfg.batch_wait_ms / 1e3, max(slack, 0.0))
            if block and len(batch) == 1 and not self._heap and wait > 0:
                self._cv.wait(timeout=wait)
                self._coalesce_into(batch)
            obs.gauge("frontend.queue_depth",
                      "admission queue depth").set(len(self._heap))
        return batch

    def _pop_live(self) -> Optional[_Pending]:
        """Earliest-deadline queued request, expiring stale ones (typed,
        counted — an expiry is an outcome, not a drop)."""
        while self._heap:
            _, _, p = heapq.heappop(self._heap)
            now = self._clock()
            if now < p.deadline:
                return p
            self.stats["expired"] += 1
            obs.counter("frontend.expired",
                        "requests whose deadline passed in queue").inc()
            self._queue_wait.observe(now - p.enq)
            p.future.set_exception(DeadlineExceeded(
                f"request for {p.key!r} expired after "
                f"{1e3 * (now - p.enq):.1f}ms in the admission queue"))
        return None

    def _coalesce_into(self, batch: List[_Pending]) -> None:
        """Greedily fuse compatible queued requests (same estimator, same
        explicit tier) up to the engine's largest shape bucket — EDF
        order, so the batch absorbs the most urgent work first."""
        first = batch[0]
        max_rows = getattr(self.engine.config, "max_batch", 1 << 30)
        rows = sum(p.rows for p in batch)
        # peek-and-pop: the heap head is always the next-earliest deadline
        while self._heap:
            head = self._heap[0][2]
            if (head.key != first.key or head.precision != first.precision
                    or rows + head.rows > max_rows):
                break
            if self._resilient and (
                    head.accuracy_target != first.accuracy_target
                    or head.allow_degraded != first.allow_degraded):
                # the resilient engine serves one fused request — members
                # must share its accuracy/degradation knobs; the plain
                # engine's typed query_many gates targets per member
                break
            heapq.heappop(self._heap)
            now = self._clock()
            if now >= head.deadline:
                self.stats["expired"] += 1
                obs.counter("frontend.expired",
                            "requests whose deadline passed in "
                            "queue").inc()
                self._queue_wait.observe(now - head.enq)
                head.future.set_exception(DeadlineExceeded(
                    f"request for {head.key!r} expired in queue"))
                continue
            batch.append(head)
            rows += head.rows

    def _dispatch(self, batch: List[_Pending]) -> None:
        cfg = self.config
        state = self.sm.state
        level = self.sm.level
        ladder_tier = cfg.brownout_tiers[level]
        tier = batch[0].precision or ladder_tier
        browned = batch[0].precision is None and ladder_tier is not None
        rows = sum(p.rows for p in batch)
        now = self._clock()
        for p in batch:
            self._queue_wait.observe(now - p.enq)
        t0 = now
        sp = obs.span("frontend.batch", key=batch[0].key, rows=rows,
                      requests=len(batch), state=state,
                      tier=tier or "config")
        # the inflight decrement must come LAST, after every member's
        # future carries its outcome (result, typed error, or a requeued
        # heap entry): drain() returns the instant heap+inflight hit
        # zero, and a decrement before set_result opens a window where a
        # drained caller reads still-unresolved futures as silent drops
        try:
            try:
                with sp:
                    if browned:
                        sp.set(browned=True)
                        obs.counter(
                            "frontend.brownout",
                            "dispatches tier-shed by queue pressure",
                            labels={"tier": tier}).inc(len(batch))
                    if self._resilient:
                        answers = self._dispatch_resilient(
                            batch, tier, level)
                    else:
                        answers = self._dispatch_plain(batch, tier)
            except InjectedFailure:
                self._requeue(batch)
                return
            except ServeError as e:
                self._resolve_error(batch, e)
                return
            except BaseException as e:   # noqa: BLE001 — a worker thread
                # cannot re-raise to anyone; the caller's future is the
                # only channel a real bug can surface through
                obs.counter("frontend.dispatch_errors",
                            "non-chaos dispatch exceptions",
                            labels={"type": type(e).__name__}).inc()
                self._resolve_error(batch, e)
                return
            dt = self._clock() - t0
            self._finish(batch, answers, browned, state, dt)
        finally:
            with self._cv:
                self._inflight -= 1
                self._cv.notify_all()

    def _dispatch_plain(self, batch: List[_Pending],
                        tier: Optional[str]) -> List[Answer]:
        """Typed fused dispatch through ``ServeEngine.query_many`` — one
        QueryRequest per member, so per-member accuracy targets gate the
        cascade row ranges independently."""
        now = self._clock()
        reqs = [QueryRequest(
            key=p.key, points=p.y, precision=tier,
            accuracy_target=p.accuracy_target,
            deadline_s=max(p.deadline - now, 1e-3)) for p in batch]
        return self.engine.query_many(reqs)

    def _dispatch_resilient(self, batch: List[_Pending],
                            tier: Optional[str], level: int
                            ) -> List[Answer]:
        """One fused dispatch through ``ResilientEngine.query`` — the
        shedding rung of the brownout ladder opts into certified degraded
        answers even when the engine's default would refuse them.  The
        fused Answer is split back into one per member, each carrying its
        slice of the per-row bounds."""
        cfg = self.config
        fused, sizes = coalesce([p.y for p in batch])
        budget_s = max(
            max(p.deadline for p in batch) - self._clock(), 1e-3)
        allow = batch[0].allow_degraded
        if level >= 2 and cfg.brownout_degraded:
            allow = True
        ans = self.engine.query(QueryRequest(
            key=batch[0].key, points=fused, precision=tier,
            accuracy_target=batch[0].accuracy_target,
            deadline_s=budget_s, allow_degraded=allow))
        parts = split(ans.value, sizes)
        offs = np.cumsum([0] + list(sizes))
        out = []
        for i, dens in enumerate(parts):
            b = (ans.rel_err_bounds[int(offs[i]):int(offs[i + 1])]
                 if ans.rel_err_bounds is not None else None)
            out.append(dataclasses.replace(
                ans, value=dens, rel_err_bounds=b,
                rel_err_bound=(float(b.max()) if b is not None and b.size
                               else ans.rel_err_bound)))
        return out

    def _requeue(self, batch: List[_Pending]) -> None:
        """Chaos on the dispatch path: retry each member (bounded), then
        shed typed — injected faults cost retries, never silent drops.
        (``_dispatch``'s finally owns the inflight decrement.)"""
        with self._cv:
            for p in batch:
                if p.retries >= self.config.max_retries:
                    self.stats["rejected"] += 1
                    self._rejected_by["retries"] = (
                        self._rejected_by.get("retries", 0) + 1)
                    obs.counter("frontend.rejected",
                                "requests shed at admission",
                                labels={"reason": "retries"}).inc()
                    p.future.set_exception(Overloaded(
                        f"request for {p.key!r} failed "
                        f"{p.retries + 1} chaos-injected dispatches",
                        reason="retries"))
                    continue
                p.retries += 1
                self.stats["retries"] += 1
                obs.counter("frontend.retries",
                            "chaos-failed dispatches requeued").inc()
                heapq.heappush(self._heap, p.entry())
            self._cv.notify_all()

    def _resolve_error(self, batch: List[_Pending], err) -> None:
        """Typed engine/bug error for every member — still an accounted
        outcome (``errored`` in the ledger), never a silent drop."""
        self.stats["errored"] += len(batch)
        for p in batch:
            p.future.set_exception(err)

    def _finish(self, batch, answers, browned, state, dispatch_s) -> None:
        now = self._clock()
        late = 0
        for p, ans in zip(batch, answers):
            if now > p.deadline:
                late += 1
                p.future.set_exception(DeadlineExceeded(
                    f"answer for {p.key!r} completed "
                    f"{1e3 * (now - p.deadline):.1f}ms past its deadline"))
                continue
            self.stats["answered"] += 1
            if ans.degraded:
                self.stats["degraded"] += 1
            if browned:
                self.stats["browned"] += 1
            ans.browned = browned
            ans.state = state
            ans.queued_ms = 1e3 * max(now - dispatch_s - p.enq, 0.0)
            ans.batch_requests = len(batch)
            p.future.set_result(ans)
        if late:
            self.stats["late"] += late
            obs.counter("frontend.late_answers",
                        "answers completed past their deadline").inc(late)
        self.stats["batches"] += 1
        obs.counter("frontend.batches", "fused dispatches").inc()
        obs.histogram("frontend.batch_rows", "query rows per fused "
                      "dispatch", lo=1, hi=1e6).observe(
            max(sum(p.rows for p in batch), 1))
        # the AIMD feedback: healthy = inside the SLO with a calm queue
        with self._lock:
            depth = len(self._heap)
            self.sm.observe(depth)
        if dispatch_s > self.config.p99_slo_ms / 1e3 or late:
            self.aimd.on_breach("slo" if not late else "late")
        elif depth < self.sm.bp_enter:
            self.aimd.on_healthy()

    # -- lifecycle --------------------------------------------------------

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Stop admitting, serve everything queued; True when empty."""
        self.sm.drain()
        deadline = None if timeout is None else self._clock() + timeout
        with self._cv:
            while self._heap or self._inflight:
                if not self._workers:
                    break              # pump-mode caller drains manually
                rem = (None if deadline is None
                       else max(deadline - self._clock(), 0.0))
                if rem == 0.0:
                    return False
                self._cv.wait(timeout=rem if rem is not None else 0.1)
        if not self._workers:
            while self.pump(1):
                pass
        with self._lock:
            return not self._heap and not self._inflight

    def close(self, timeout: float = 30.0) -> None:
        """Drain, then stop the dispatcher threads."""
        self.drain(timeout)
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for t in self._workers:
            t.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- telemetry --------------------------------------------------------

    @property
    def state(self) -> str:
        return self.sm.state

    def report(self) -> dict:
        """JSON-safe overload report: every submitted request accounted
        for by outcome (the zero-silent-drop ledger), queue-wait tail,
        admission-rate and state-machine history."""
        h = self._queue_wait
        return {
            "state": self.sm.state,
            "stats": dict(self.stats),
            "rejected_by": dict(self._rejected_by),
            "admit_rate": round(self.aimd.rate, 2),
            "queue_depth": len(self._heap),
            "queue_wait_ms": {
                "p50": round(1e3 * h.quantile(0.50), 3),
                "p99": round(1e3 * h.quantile(0.99), 3),
                "count": h.count,
            },
            "transitions": [f"{a}->{b}" for a, b in self.sm.transitions],
        }

    def unaccounted(self) -> int:
        """Requests that neither resolved nor were typed-rejected — the
        quantity the soak asserts is ZERO (answered + degraded counts are
        inside ``answered``; expired/late/rejected are typed)."""
        s = self.stats
        return (s["submitted"] + s["synthetic"] - s["rejected"]
                - s["answered"] - s["expired"] - s["late"] - s["errored"]
                - len(self._heap) - self._inflight)


__all__ = ["ACCEPTING", "BACKPRESSURE", "SHEDDING", "DRAINING",
           "FrontendConfig", "FrontendAnswer", "TokenBucket",
           "AimdController", "AdmissionStateMachine", "AsyncFrontend"]
