"""Serving configuration: backend, estimator method, batching policy.

One frozen config object controls the whole request path — which density
method is served, which execution backend evaluates it, and how ragged query
traffic is coalesced into jit-stable shape buckets.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple, Union

from repro.kernels.precision import validate as _validate_precision

Backend = Literal["jnp", "pallas", "ring"]
Method = Literal["kde", "sdkde", "laplace"]
Precision = Literal["f32", "bf16", "bf16x2"]   # = kernels.precision.PRECISIONS
# a *serving* tier is an exact GEMM tier or the RFF fast tier; the fit
# tier and the feature-GEMM tier stay exact
ServeTier = Literal["f32", "bf16", "bf16x2", "rff"]
BlockArg = Union[int, Literal["auto"]]


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving configuration (hashable; safe to close over in jit).

    Batching: a query batch of ``m`` rows is padded up to the smallest shape
    bucket ≥ m.  Buckets double geometrically from ``min_batch`` to
    ``max_batch`` and are rounded up to tile/ring multiples, so arbitrary
    ragged traffic hits at most ``log2(max/min)+1`` distinct compiled shapes
    per estimator instead of one compile per distinct batch size.
    """

    backend: Backend = "jnp"
    method: Method = "sdkde"

    # estimator knobs (mirrors repro.core.estimator.EstimatorConfig)
    block: int = 1024            # jnp streaming column-block size
    block_m: BlockArg = 128      # Pallas row tile (int or "auto" = autotuned)
    block_n: BlockArg = 512      # Pallas column tile (int or "auto")
    interpret: bool = True       # Pallas interpret mode (CPU validation)
    score_h: Optional[float] = None
    # Default serving tier: a GEMM-operand tier (kernels/precision.py) or
    # "rff", the random-feature fast tier (kernels/flash_rff.py).  A
    # QueryRequest precision pin overrides per request (precedence:
    # request pin > explicit config > planner); the registry caches
    # prepared train tensors per exact tier.
    precision: ServeTier = "f32"
    # Tier for the one-time O(n²·d) debias fit.  The fit is amortized off
    # the latency path, so it defaults to full precision regardless of the
    # serving tier — reduced-precision *queries* perturb one GEMM, while a
    # reduced-precision fit would bake its error into every future answer.
    fit_precision: Precision = "f32"
    # Cluster pruning (kernels/spatial.py, pallas backend): "auto" prunes
    # exactly (epsilon=0, certified-underflow tiles only) once the train
    # set is large enough; "off" streams every tile; a float is the
    # per-point contribution threshold epsilon.  The registry caches the
    # clustered ordering + tile metadata per tier at fit time, so pruning
    # costs only the cheap bounds prepass on the query path.
    prune: Union[str, float] = "auto"

    # micro-batching policy
    min_batch: int = 128         # smallest shape bucket
    max_batch: int = 4096        # largest shape bucket (larger batches chunk)
    cache_buckets: int = 8       # LRU capacity of jitted shape buckets

    # streaming (repro.stream): maintain the registered dataset
    # incrementally under registry.append()/evict_ids() instead of
    # refitting.  ``staleness_budget`` is how many applied update
    # generations a query may be served across before the engine must
    # publish a fresh snapshot (0 = always fresh); ``stream_slack`` is the
    # per-cluster append headroom of the Pallas layout;
    # ``stream_background`` builds snapshots on a worker thread so queries
    # keep serving generation g while g+1 prepares.
    stream: bool = False
    staleness_budget: int = 0
    stream_slack: float = 0.5
    stream_background: bool = False

    # execution planning (repro.plan): "auto" resolves every knob still at
    # its dataclass default through the cost-model planner at fit time —
    # explicitly-set knobs always win (override precedence, see
    # docs/architecture.md "Execution planning").  ``accuracy_target`` is
    # the planner's relative-accuracy budget; None = f32-grade (1e-5).
    plan: Literal["off", "auto"] = "off"
    accuracy_target: Optional[float] = None

    # RFF fast tier + accuracy cascade (kernels/flash_rff.py,
    # serve/cascade.py).  "auto" fits the per-generation RFF state lazily
    # on the first cascade-routed request (requests without an accuracy
    # target never pay for it); "on" fits it eagerly with the debias
    # pass; "off" disables the tier (an "rff" pin then raises).
    rff: Literal["off", "auto", "on"] = "auto"
    rff_features: int = 8192     # D: total cos+sin features per dataset
    rff_pilot: int = 256         # pilot control-variate mixture size
    rff_groups: int = 32         # frequency groups behind the band (the
                                 # band's t-statistic dof; see flash_rff)
    rff_precision: Precision = "f32"   # feature-GEMM operand tier

    def __post_init__(self):
        if self.min_batch <= 0 or self.max_batch < self.min_batch:
            raise ValueError(
                f"bad bucket range [{self.min_batch}, {self.max_batch}]"
            )
        if self.cache_buckets < 1:
            raise ValueError("cache_buckets must be >= 1")
        if self.precision != "rff":
            _validate_precision(self.precision)
        for p in (self.fit_precision, self.rff_precision):
            _validate_precision(p)
        if self.rff not in ("off", "auto", "on"):
            raise ValueError(
                f"bad rff {self.rff!r} ('off', 'auto', or 'on')")
        if self.precision == "rff" and self.rff == "off":
            raise ValueError(
                "precision='rff' needs the RFF tier enabled (rff='auto' "
                "or 'on')")
        if self.rff_pilot < 1 or self.rff_groups < 2:
            raise ValueError("need rff_pilot >= 1 and rff_groups >= 2")
        if self.rff_features < 2 * self.rff_groups \
                or self.rff_features % (2 * self.rff_groups):
            raise ValueError(
                f"rff_features must be a positive multiple of "
                f"2·rff_groups, got {self.rff_features} with "
                f"groups={self.rff_groups}")
        for b in (self.block_m, self.block_n):
            if not (b == "auto" or (isinstance(b, int) and b > 0)):
                raise ValueError(f"bad Pallas block {b!r} (int or 'auto')")
        p = self.prune
        if not (p in ("auto", "off")
                or (isinstance(p, (int, float)) and not isinstance(p, bool)
                    and p >= 0)):
            raise ValueError(
                f"bad prune {p!r} ('auto', 'off', or epsilon >= 0)"
            )
        if self.staleness_budget < 0:
            raise ValueError("staleness_budget must be >= 0")
        if self.stream_slack < 0:
            raise ValueError("stream_slack must be >= 0")
        if self.plan not in ("off", "auto"):
            raise ValueError(f"bad plan {self.plan!r} ('off' or 'auto')")
        if self.accuracy_target is not None \
                and not (self.accuracy_target > 0):
            raise ValueError(
                f"accuracy_target must be > 0, got {self.accuracy_target!r}"
            )
        if self.stream and self.backend == "ring":
            raise ValueError(
                "streaming estimators support the jnp/pallas backends "
                "(the ring shards at fit time; re-sharding per append is "
                "a full refit by construction)"
            )

    @property
    def exact_precision(self) -> str:
        """The exact GEMM tier behind the default serving tier — what the
        registry prepares train columns at and what cascade escalations
        run when the default tier is ``"rff"``."""
        return "f32" if self.precision == "rff" else self.precision

    def row_multiple(self, ring_size: int = 1,
                     block_m: Optional[int] = None) -> int:
        """Row-count multiple every dispatched batch must honor.

        Pallas tiles rows by ``block_m``; the ring shards rows over
        ``ring_size`` devices; the jnp path is shape-agnostic but still
        bucketed for jit-cache stability.  When the config says
        ``block_m="auto"`` the caller passes the fit-time tuned tile
        (``PreparedEstimator.block_m``) — before a fit resolves it, the
        ladder falls back to the 128-row default tile.
        """
        if self.backend == "pallas":
            bm = block_m if block_m is not None else self.block_m
            return bm if isinstance(bm, int) else 128
        if self.backend == "ring":
            return max(1, ring_size)
        return 1

    def bucket_sizes(self, ring_size: int = 1,
                     block_m: Optional[int] = None) -> Tuple[int, ...]:
        """The geometric ladder of padded batch shapes this config serves."""
        mult = self.row_multiple(ring_size, block_m)
        sizes, b = [], self.min_batch
        while True:
            sizes.append(_round_up(min(b, self.max_batch), mult))
            if b >= self.max_batch:
                break
            b *= 2
        return tuple(dict.fromkeys(sizes))

    def bucket_for(self, m: int, ring_size: int = 1,
                   block_m: Optional[int] = None) -> int:
        """Smallest shape bucket that fits an ``m``-row query batch."""
        if m <= 0:
            raise ValueError(f"empty query batch (m={m})")
        sizes = self.bucket_sizes(ring_size, block_m)
        for b in sizes:
            if m <= b:
                return b
        return sizes[-1]  # chunked by the engine


__all__ = ["Backend", "Method", "Precision", "ServeTier", "BlockArg",
           "ServeConfig"]
