"""Serving configuration: backend, estimator method, batching policy.

One frozen config object controls the whole request path — which density
method is served, which execution backend evaluates it, and how ragged query
traffic is coalesced into jit-stable shape buckets.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

Backend = Literal["jnp", "pallas", "ring"]
Method = Literal["kde", "sdkde", "laplace"]


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving configuration (hashable; safe to close over in jit).

    Batching: a query batch of ``m`` rows is padded up to the smallest shape
    bucket ≥ m.  Buckets double geometrically from ``min_batch`` to
    ``max_batch`` and are rounded up to tile/ring multiples, so arbitrary
    ragged traffic hits at most ``log2(max/min)+1`` distinct compiled shapes
    per estimator instead of one compile per distinct batch size.
    """

    backend: Backend = "jnp"
    method: Method = "sdkde"

    # estimator knobs (mirrors repro.core.estimator.EstimatorConfig)
    block: int = 1024            # jnp streaming column-block size
    block_m: int = 128           # Pallas row tile
    block_n: int = 512           # Pallas column tile
    interpret: bool = True       # Pallas interpret mode (CPU validation)
    score_h: Optional[float] = None

    # micro-batching policy
    min_batch: int = 128         # smallest shape bucket
    max_batch: int = 4096        # largest shape bucket (larger batches chunk)
    cache_buckets: int = 8       # LRU capacity of jitted shape buckets

    def __post_init__(self):
        if self.min_batch <= 0 or self.max_batch < self.min_batch:
            raise ValueError(
                f"bad bucket range [{self.min_batch}, {self.max_batch}]"
            )
        if self.cache_buckets < 1:
            raise ValueError("cache_buckets must be >= 1")

    def row_multiple(self, ring_size: int = 1) -> int:
        """Row-count multiple every dispatched batch must honor.

        Pallas tiles rows by ``block_m``; the ring shards rows over
        ``ring_size`` devices; the jnp path is shape-agnostic but still
        bucketed for jit-cache stability.
        """
        if self.backend == "pallas":
            return self.block_m
        if self.backend == "ring":
            return max(1, ring_size)
        return 1

    def bucket_sizes(self, ring_size: int = 1) -> Tuple[int, ...]:
        """The geometric ladder of padded batch shapes this config serves."""
        mult = self.row_multiple(ring_size)
        sizes, b = [], self.min_batch
        while True:
            sizes.append(_round_up(min(b, self.max_batch), mult))
            if b >= self.max_batch:
                break
            b *= 2
        return tuple(dict.fromkeys(sizes))

    def bucket_for(self, m: int, ring_size: int = 1) -> int:
        """Smallest shape bucket that fits an ``m``-row query batch."""
        if m <= 0:
            raise ValueError(f"empty query batch (m={m})")
        for b in self.bucket_sizes(ring_size):
            if m <= b:
                return b
        return self.bucket_sizes(ring_size)[-1]  # chunked by the engine


__all__ = ["Backend", "Method", "ServeConfig"]
