"""Estimator registry: fit once, serve forever.

SD-KDE has exactly the prefill/decode asymmetry serving systems exploit: the
empirical-score debias of the train set is O(n²·d) and depends only on the
dataset, while each query batch is a cheap O(n·m·d) GEMM against the (fixed)
debiased points.  The registry performs the expensive pass once per dataset
and caches a *prepared* estimator — debiased samples, transposed column
layout, precomputed row norms, normalization constant, and (for the ring
backend) the sharded placement — so the serving engine never repeats train-
side work per request.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro import fault_injection, obs
from repro.core import bandwidth as bw
from repro.core import kde as ref
from repro.core.bandwidth import gaussian_norm_const
from repro.serve.config import ServeConfig
from repro.serve.errors import UnknownKey


@dataclasses.dataclass
class PreparedEstimator:
    """Everything query evaluation needs, precomputed at fit time."""

    key: str
    config: ServeConfig
    h: float
    n_true: int              # real (unpadded) train count, for normalization
    d: int
    generation: int          # bumped per fit; cache keys include it so a
                             # refit/evict+refit never serves stale executables
    points: jnp.ndarray      # (n, d) train points (debiased for sdkde)
    norm: float              # n_true · (2π)^{d/2} · h^d
    # pallas backend: fit-time resolved launch tiles ("auto" in the config
    # consults the kernels/autotune.py model once per fit); the prepared
    # padded/transposed column layouts live in ``_columns``, one entry per
    # precision tier (the fit tier eagerly, others lazily on first query).
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    # ring backend: device mesh + row-sharded (padded) points
    mesh: object = None
    x_sharded: Optional[jnp.ndarray] = None
    # streaming (config.stream): the incrementally maintained live state;
    # all prepared-state accessors delegate to its published snapshot
    stream: object = None
    # execution planning (config.plan == "auto"): the repro.plan
    # ExecutionPlan this estimator's knobs were resolved from, kept for
    # tracing (every dispatch span carries plan.plan_id) and prewarming.
    # None when the config pinned every knob by hand.
    plan: object = None
    # RFF fast tier (kernels/flash_rff.py): the per-generation random-
    # feature state behind the accuracy cascade.  Fitted eagerly with the
    # debias pass when config.rff == "on", lazily on the first cascade-
    # routed request under "auto"; a streaming estimator's tier re-syncs
    # to each served snapshot (incremental by id diff, full refit on
    # layout-epoch rebuilds).
    rff: object = None
    _columns: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def ring_size(self) -> int:
        return self.mesh.devices.size if self.mesh is not None else 1

    def columns_for(self, precision: str):
        """Prepared train tensors for one tier (built once, then cached).

        Returns the ``ops.TrainColumns`` the prepared fast path consumes;
        the per-tier cache is what lets one registered dataset serve f32
        and bf16 traffic side by side without re-padding/transposing per
        request.  When the config enables pruning, every tier is prepared
        ``clustered`` and all tiers share ONE spatial index (clustered
        once at fit), so their tile layouts — and the engine's bucket
        executables — agree across tiers.
        """
        if self.stream is not None:
            return self.stream.columns_for(precision)
        if precision not in self._columns:
            from repro.kernels import ops

            # cluster only when pruning can actually engage for this set
            # ("auto" below the size threshold stays dense end to end)
            clustered = ops.resolve_prune(
                self.config.prune, self.n_true, self.block_n or 512
            ) is not None
            shared = next(
                (c.index for c in self._columns.values()
                 if c.index is not None), None,
            )
            self._columns[precision] = ops.prepare_train_columns(
                self.points, block_n=self.block_n, precision=precision,
                clustered=clustered, index=shared,
            )
        return self._columns[precision]

    # Convenience views of the serving-tier prepared state (pallas backend;
    # None elsewhere).  ``_columns`` is the single source of truth.
    def _default_columns(self):
        if self.config.backend != "pallas":
            return None
        return self.columns_for(self.config.exact_precision)

    @property
    def xt(self) -> Optional[jnp.ndarray]:
        cols = self._default_columns()
        return None if cols is None else cols.xt

    @property
    def xt_lo(self) -> Optional[jnp.ndarray]:
        cols = self._default_columns()
        return None if cols is None else cols.xt_lo

    @property
    def nrm_x(self) -> Optional[jnp.ndarray]:
        cols = self._default_columns()
        return None if cols is None else cols.nrm_x


class _RFFTier:
    """Lifecycle of one estimator's RFF fast-tier state.

    Owns the fit (once per static generation) and the streaming refit
    policy: consecutive snapshots are diffed by live id — appended,
    evicted AND debias-shifted rows fold into the exact feature sums as
    an O(b·D·d/2) delta — while a layout-epoch rebuild (re-cluster)
    triggers the full refit, since the pilot anchors are stale by
    construction then.
    """

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.state = None
        self._epoch: Optional[int] = None
        self._gen: Optional[int] = None
        self._ids: Optional[np.ndarray] = None
        self._points: Optional[np.ndarray] = None
        self._lock = threading.Lock()

    def _fit(self, points, h: float):
        from repro.kernels import flash_rff

        cfg = self.cfg
        with obs.span("rff.fit", n=int(np.asarray(points).shape[0]),
                      features=cfg.rff_features):
            self.state = flash_rff.fit(
                points, h, n_features=cfg.rff_features,
                n_pilot=cfg.rff_pilot, groups=cfg.rff_groups,
            )
        obs.counter("rff.fits", "RFF tier fits (full featurization "
                    "passes)").inc()

    def serving(self, prep: "PreparedEstimator", snap=None):
        """The tier's serving tensors, synced to ``snap`` if streaming."""
        from repro.kernels import flash_rff

        with self._lock:
            if prep.stream is None:
                if self.state is None:
                    self._fit(prep.points, prep.h)
                return self.state.serving()
            if snap is None:
                snap = prep.stream.ensure(self.cfg.staleness_budget)
            if snap.ids is None:
                return None
            if self.state is None or snap.layout_epoch != self._epoch:
                self._fit(snap.points, prep.h)
            elif snap.gen != self._gen:
                self._sync(flash_rff, snap)
            if snap.gen != self._gen or snap.layout_epoch != self._epoch:
                self._epoch = snap.layout_epoch
                self._gen = snap.gen
                self._ids = np.asarray(snap.ids, np.int64)
                self._points = np.asarray(snap.points, np.float64)
            return self.state.serving()

    def _sync(self, flash_rff, snap) -> None:
        """Fold the id/value diff between the last-synced snapshot and
        ``snap`` into the accumulators.  Live ids are monotone, so the
        diff is two sorted-set operations; sd-kde's incremental debias
        also *shifts* surviving rows, which the value compare catches
        (shifted row = evict old coords + append new ones)."""
        ids = np.asarray(snap.ids, np.int64)
        pts = np.asarray(snap.points, np.float64)
        old_ids, old_pts = self._ids, self._points
        keep_new = np.isin(ids, old_ids)
        keep_old = np.isin(old_ids, ids)
        added = [pts[~keep_new]]
        removed = [old_pts[~keep_old]]
        moved = np.any(pts[keep_new] != old_pts[keep_old], axis=1)
        if moved.any():
            added.append(pts[keep_new][moved])
            removed.append(old_pts[keep_old][moved])
        flash_rff.update(self.state,
                         added=np.concatenate(added),
                         removed=np.concatenate(removed))
        obs.counter("rff.incremental_syncs",
                    "RFF feature-sum delta updates across stream "
                    "generations").inc()


class EstimatorRegistry:
    """Named cache of prepared estimators.

    ``fit`` is idempotent per key: re-registering an existing key returns
    the cached estimator without re-running the quadratic score pass
    (``n_fits`` counts actual debias/prepare passes — tested).  Pass
    ``refit=True`` to force a refresh after a dataset update.
    """

    def __init__(self, config: ServeConfig | None = None):
        self.config = config or ServeConfig()
        self._store: Dict[str, PreparedEstimator] = {}
        self.n_fits = 0

    def __contains__(self, key: str) -> bool:
        return key in self._store

    def keys(self):
        return tuple(self._store)

    def get(self, key: str) -> PreparedEstimator:
        if key not in self._store:
            raise UnknownKey(
                f"estimator {key!r} not registered (have {list(self._store)})"
            )
        return self._store[key]

    def evict(self, key: str) -> None:
        self._store.pop(key, None)

    # -- streaming updates (config.stream estimators) --------------------

    def _stream_of(self, key: str):
        prep = self.get(key)
        if prep.stream is None:
            raise ValueError(
                f"estimator {key!r} is not streaming (register it with "
                "ServeConfig(stream=True) to append/evict points)"
            )
        return prep.stream

    def rff_serving(self, prep: PreparedEstimator, snap=None):
        """The RFF fast tier's serving tensors for one estimator, or None
        when the tier is disabled/unsupported.  Lazy under
        ``config.rff == "auto"``: the first cascade-routed request pays
        the one-time featurization, everything after reuses it until the
        generation moves."""
        if prep.rff is None:
            return None
        return prep.rff.serving(prep, snap=snap)

    def append(self, key: str, xs):
        """Fold new train points into a streaming estimator — the O(n·b·d)
        delta pass, never the O(n²·d) refit.  Returns the assigned ids."""
        return self._stream_of(key).append(xs)

    def evict_ids(self, key: str, ids) -> int:
        """Remove train points (by the ids ``append`` returned) from a
        streaming estimator.  Not to be confused with ``evict(key)``,
        which drops a whole registered estimator."""
        return self._stream_of(key).evict(ids)

    def slide(self, key: str, xs):
        """Sliding-window update: append ``xs``, evict the oldest as many."""
        return self._stream_of(key).slide(xs)

    def fit(
        self,
        key: str,
        x: jnp.ndarray,
        h: Optional[float] = None,
        config: ServeConfig | None = None,
        refit: bool = False,
    ) -> PreparedEstimator:
        if key in self._store and not refit:
            return self._store[key]
        cfg = config or self.config
        fault_injection.fire("registry.fit", key=key)
        self.n_fits += 1
        prep = self._prepare(key, jnp.asarray(x, jnp.float32), h, cfg)
        self._store[key] = prep
        return prep

    # -- the one-time expensive pass ------------------------------------

    def _prepare(
        self, key: str, x: jnp.ndarray, h: Optional[float], cfg: ServeConfig
    ) -> PreparedEstimator:
        n, d = x.shape
        if h is None:
            h = (
                bw.sdkde_bandwidth(x)
                if cfg.method == "sdkde"
                else bw.silverman_bandwidth(x)
            )
        h = float(h)

        # Plan resolution happens exactly once per fit, before any backend
        # branch: knobs still at their dataclass defaults are filled from
        # the cost-model planner; explicitly-set knobs always win.
        plan_obj = None
        if cfg.plan == "auto":
            from repro.plan import resolve_config

            cfg, plan_obj = resolve_config(cfg, n=n, d=d)

        if cfg.stream:
            return self._prepare_stream(key, x, h, cfg, plan_obj)

        points = self._debias(x, h, cfg) if cfg.method == "sdkde" else x
        prep = PreparedEstimator(
            key=key, config=cfg, h=h, n_true=n, d=d,
            generation=self.n_fits, points=points,
            norm=n * gaussian_norm_const(d, 1.0) * h**d,
            plan=plan_obj,
        )

        self._attach_rff(prep, cfg)

        if cfg.backend == "pallas":
            from repro.kernels import ops

            prep.block_m, prep.block_n = self._resolve_fit_blocks(cfg, n, d)
            clustered = ops.resolve_prune(
                cfg.prune, n, prep.block_n
            ) is not None
            prep._columns[cfg.exact_precision] = ops.prepare_train_columns(
                points, block_n=prep.block_n, precision=cfg.exact_precision,
                clustered=clustered,
            )
        elif cfg.backend == "ring":
            from repro.distributed import ring

            prep.mesh = ring.default_mesh()
            prep.x_sharded = ring.shard_points(points, prep.mesh, ("data",))
        return prep

    @staticmethod
    def _attach_rff(prep: PreparedEstimator, cfg: ServeConfig) -> None:
        """Attach (and under ``rff="on"`` eagerly fit) the RFF fast tier
        — amortized alongside the debias pass, once per generation."""
        from repro.kernels import flash_rff

        if cfg.rff == "off" or not flash_rff.supports(cfg.method,
                                                      cfg.backend):
            return
        prep.rff = _RFFTier(cfg)
        if cfg.rff == "on" and prep.stream is None:
            prep.rff._fit(prep.points, prep.h)

    @staticmethod
    def _resolve_fit_blocks(cfg: ServeConfig, n: int, d: int):
        """Resolve "auto" launch tiles once per fit: rows = the largest
        shape bucket this estimator will ever dispatch, cols = the train
        count.  The resolved tiles shape the bucket ladder AND the
        prepared column padding, so they live on the estimator.
        vmem_itemsize=4 gates feasibility at the widest operand tier
        (f32 / bf16x2), because per-request precision overrides reuse
        this one tile across every tier."""
        from repro.kernels import autotune

        return autotune.resolve_blocks(
            cfg.block_m, cfg.block_n, rows=cfg.max_batch, cols=n, d=d,
            out_width=1, precision=cfg.precision,
            measure=False if cfg.interpret else None,
            vmem_itemsize=4, pruned=cfg.prune != "off",
        )

    def _prepare_stream(
        self, key: str, x: jnp.ndarray, h: float, cfg: ServeConfig,
        plan_obj: object = None,
    ) -> PreparedEstimator:
        """Fit a streaming estimator: the one full score pass happens in
        the stream's constructor; every later ``append``/``evict_ids`` is
        an O(n·b·d) delta against this state."""
        from repro.stream import StreamConfig, StreamingSDKDE

        n, d = x.shape
        prep = PreparedEstimator(
            key=key, config=cfg, h=h, n_true=n, d=d,
            generation=self.n_fits, points=x,
            norm=n * gaussian_norm_const(d, 1.0) * h**d,
            plan=plan_obj,
        )
        block_n = 512
        if cfg.backend == "pallas":
            prep.block_m, prep.block_n = self._resolve_fit_blocks(cfg, n, d)
            block_n = prep.block_n
        prep.stream = StreamingSDKDE(
            x, h, method=cfg.method, score_h=cfg.score_h,
            backend=cfg.backend, block_n=block_n,
            precision=cfg.precision,
            config=StreamConfig(
                slack=cfg.stream_slack,
                staleness_budget=cfg.staleness_budget,
                background=cfg.stream_background,
            ),
        )
        prep.points = prep.stream.snapshot().points
        self._attach_rff(prep, cfg)
        return prep

    def _debias(self, x: jnp.ndarray, h: float, cfg: ServeConfig):
        """The O(n²·d) score pass — runs exactly once per registered key.

        Delegates to the core estimator (one backend dispatch for the whole
        repo); the only serve-side extra is ring padding, since a registered
        dataset's size need not divide the ring.
        """
        from repro.core.estimator import SDKDE, EstimatorConfig

        n = x.shape[0]
        if cfg.backend == "ring":
            from repro.distributed import ring

            x = ref.pad_rows(x, ring.default_mesh().devices.size)
        est_cfg = EstimatorConfig(
            backend=cfg.backend, block=cfg.block,
            block_m=cfg.block_m, block_n=cfg.block_n,
            interpret=cfg.interpret, score_h=cfg.score_h,
            precision=cfg.fit_precision,
            # like fit_precision: the amortized fit never spends its
            # epsilon budget — exact (underflow-only) pruning at most
            prune="auto" if cfg.prune != "off" else "off",
        )
        return SDKDE(h, est_cfg).fit(x).x_sd[:n]


__all__ = ["PreparedEstimator", "EstimatorRegistry"]
