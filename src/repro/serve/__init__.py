"""Query-serving subsystem for KDE / SD-KDE / Laplace-KDE estimators.

Turns the reproduction's batch estimators into an online service: fit (and
debias) once per dataset via the ``EstimatorRegistry``, then answer ragged
query traffic through the ``ServeEngine``'s shape-bucketed micro-batcher on
any of the three execution backends (``jnp`` / ``pallas`` / ``ring``).

    from repro.serve import ServeConfig, ServeEngine

    eng = ServeEngine(ServeConfig(backend="pallas", method="sdkde"))
    eng.register("my-dataset", x_train)          # O(n²·d) debias, once
    dens = eng.query("my-dataset", y_queries)    # cheap GEMM per batch
    print(eng.latency.summary())
"""

from repro.serve.batching import ShapeBucketCache, coalesce, pad_queries, split
from repro.serve.config import Backend, Method, ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.errors import (BadRequest, DeadlineExceeded, Degraded,
                                Overloaded, ServeError, UnknownKey)
from repro.serve.frontend import (AdmissionStateMachine, AimdController,
                                  AsyncFrontend, FrontendAnswer,
                                  FrontendConfig, TokenBucket)
from repro.serve.registry import EstimatorRegistry, PreparedEstimator
from repro.serve.resilience import (ResilienceConfig, ResilientAnswer,
                                    ResilientEngine)
from repro.serve.stats import LatencyRecorder, LatencySummary

__all__ = [
    "Backend", "Method", "ServeConfig",
    "EstimatorRegistry", "PreparedEstimator",
    "ServeEngine",
    "ResilienceConfig", "ResilientAnswer", "ResilientEngine",
    "AsyncFrontend", "FrontendAnswer", "FrontendConfig",
    "AdmissionStateMachine", "AimdController", "TokenBucket",
    "ServeError", "UnknownKey", "BadRequest", "DeadlineExceeded",
    "Overloaded", "Degraded",
    "ShapeBucketCache", "coalesce", "pad_queries", "split",
    "LatencyRecorder", "LatencySummary",
]
