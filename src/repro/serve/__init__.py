"""Query-serving subsystem for KDE / SD-KDE / Laplace-KDE estimators.

Turns the reproduction's batch estimators into an online service: fit (and
debias) once per dataset via the ``EstimatorRegistry``, then answer ragged
query traffic through the ``ServeEngine``'s shape-bucketed micro-batcher on
any of the three execution backends (``jnp`` / ``pallas`` / ``ring``).

The query surface is typed (``serve/api.py``): a ``QueryRequest`` carries
points, an optional accuracy target, a relative deadline, and a precision
pin; every engine returns an ``Answer`` with the densities, a certified
per-row error bound, and the tier path the accuracy cascade took
(``serve/cascade.py`` — the RFF fast tier answers what its band certifies,
the pruned exact kernels take the rest).

    from repro.serve import QueryRequest, ServeConfig, ServeEngine

    eng = ServeEngine(ServeConfig(backend="pallas", method="sdkde"))
    eng.register("my-dataset", x_train)          # O(n²·d) debias, once
    ans = eng.query(QueryRequest(key="my-dataset", points=y_queries,
                                 accuracy_target=1e-2))
    print(ans.tier, ans.rel_err_bound, eng.latency.summary())
"""

from repro.serve.api import Answer, QueryRequest, RFF_TIER
from repro.serve.batching import ShapeBucketCache, coalesce, pad_queries, split
from repro.serve.cascade import CascadeResult
from repro.serve.config import Backend, Method, ServeConfig, ServeTier
from repro.serve.engine import ServeEngine
from repro.serve.errors import (BadRequest, DeadlineExceeded, Degraded,
                                Overloaded, ServeError, UnknownKey)
from repro.serve.frontend import (AdmissionStateMachine, AimdController,
                                  AsyncFrontend, FrontendAnswer,
                                  FrontendConfig, TokenBucket)
from repro.serve.registry import EstimatorRegistry, PreparedEstimator
from repro.serve.resilience import (ResilienceConfig, ResilientAnswer,
                                    ResilientEngine)
from repro.serve.stats import LatencyRecorder, LatencySummary

__all__ = [
    "QueryRequest", "Answer", "RFF_TIER", "CascadeResult",
    "Backend", "Method", "ServeConfig", "ServeTier",
    "EstimatorRegistry", "PreparedEstimator",
    "ServeEngine",
    "ResilienceConfig", "ResilientAnswer", "ResilientEngine",
    "AsyncFrontend", "FrontendAnswer", "FrontendConfig",
    "AdmissionStateMachine", "AimdController", "TokenBucket",
    "ServeError", "UnknownKey", "BadRequest", "DeadlineExceeded",
    "Overloaded", "Degraded",
    "ShapeBucketCache", "coalesce", "pad_queries", "split",
    "LatencyRecorder", "LatencySummary",
]
