"""Certificate-gated accuracy cascade: RFF fast tier, exact escalation.

The router behind the typed query API (``serve/api.py``): a request with
an ``accuracy_target`` first runs the random-feature tier
(``kernels/flash_rff.py``) — one small feature GEMM regardless of train
size — and compares each query's certified band against its target.
Rows whose band fits are answered immediately; the rest escalate to the
pruned exact kernel through the engine's normal bucket dispatch.  A
``precision="rff"`` pin skips the gate and answers everything at the
fast tier (band reported as-is); an exact-tier pin skips the fast tier
entirely.

Certified bounds compose per row: fast-tier rows carry their RFF band,
escalated rows the exact tier's accuracy ladder rtol
(``plan/planner.TIER_RTOL``) plus any explicit prune-epsilon budget —
the same per-row-tile certificate machinery the pruned kernels already
account their error against.  The acceptance contract
(``benchmarks/rff_cascade.py``, gated) is that realized error never
exceeds the per-query bound.

Every routing decision is observable: ``serve.cascade_hits`` /
``serve.cascade_escalations`` counters, a ``serve.cascade_band``
width histogram, and ``cascade=``/``hits=`` attributes on the dispatch
span.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import flash_rff
from repro.plan.planner import TIER_RTOL
from repro.serve.api import RFF_TIER

#: Band rows sampled into the width histogram per dispatch (bounded so a
#: 4096-row batch doesn't pay 4096 histogram inserts on the hot path).
_BAND_SAMPLE = 32

# One jitted evaluator for every estimator/generation: the serving
# tensors arrive as a pytree argument, so a refit or generation flip
# reuses the compiled program for equal shapes instead of recompiling.
_eval_jit = jax.jit(flash_rff.eval_density,
                    static_argnames=("precision", "z"))


class CascadeResult(NamedTuple):
    """What one cascade dispatch resolved to."""

    value: jnp.ndarray          # (m,) densities
    bounds: np.ndarray          # (m,) certified relative-error bounds
    hits: int                   # rows answered at the RFF tier
    escalated: int              # rows escalated to the exact tier
    path: Tuple[str, ...]       # tiers visited, in order
    esc_rows: np.ndarray        # (m,) bool — which rows escalated


def exact_bound(tier: str, prune) -> float:
    """Certified relative bound of one exact-tier dispatch: the accuracy
    ladder's tier rtol plus an explicit prune-epsilon budget (exact
    "auto" pruning drops only certified-underflow tiles — no budget)."""
    eps = float(prune) if isinstance(prune, (int, float)) \
        and not isinstance(prune, bool) else 0.0
    return TIER_RTOL.get(tier, TIER_RTOL["f32"]) + eps


def engaged(cfg, prep, tier: str,
            target: Optional[np.ndarray]) -> bool:
    """Whether this request routes through the cascade at all.

    An ``"rff"`` pin always engages; otherwise the config must enable
    the tier, the estimator must support it (Gaussian kernel, non-ring
    backend) and the request must carry an accuracy target to gate on.
    """
    if getattr(cfg, "rff", "off") == "off":
        return tier == RFF_TIER
    if not flash_rff.supports(cfg.method, cfg.backend):
        return False
    return tier == RFF_TIER or target is not None


def evaluate(cfg, serving, y: jnp.ndarray,
             bucket: Optional[int] = None
             ) -> Tuple[np.ndarray, np.ndarray]:
    """One fast-tier evaluation: ``(p, band)`` as (m,) float64 arrays.

    Pads the batch to ``bucket`` rows before the jitted evaluator so
    ragged traffic reuses compiled shapes, then slices back.  Shared by
    the engine cascade and the resilient layer's pre-shard cascade.
    """
    m = int(y.shape[0])
    if bucket is None or bucket < m:
        bucket = m
    yp = jnp.pad(y, ((0, bucket - m), (0, 0))) if bucket > m else y
    p, band = _eval_jit(serving, yp, precision=cfg.rff_precision)
    return (np.asarray(p[:m], np.float64),
            np.asarray(band[:m], np.float64))


def run(engine, prep, y: jnp.ndarray, tier: str,
        target: Optional[np.ndarray], *,
        snap=None) -> Optional[CascadeResult]:
    """Route one (possibly fused) query batch through the cascade.

    ``tier`` is the precedence-resolved tier — ``"rff"`` pins the fast
    tier, anything else is the escalation tier.  ``target`` is the
    per-row accuracy-target vector (fused ``query_many`` batches carry
    per-request targets), or None when only a pin engaged the cascade.
    Returns None when the RFF state is unavailable (unsupported method,
    ``rff="off"`` while pinned — the caller falls back to exact and, for
    a hard ``"rff"`` pin, raises).
    """
    cfg = prep.config
    serving = engine.registry.rff_serving(prep, snap=snap)
    if serving is None:
        return None
    pinned = tier == RFF_TIER
    exact_tier = cfg.exact_precision if pinned else tier

    m = int(y.shape[0])
    p, band = evaluate(cfg, serving, y,
                       cfg.bucket_for(m, prep.ring_size, prep.block_m))

    if pinned or target is None:
        mask = np.zeros(m, bool)                  # pin: nothing escalates
    else:
        mask = band > target
    hits = int(m - mask.sum())
    esc = int(mask.sum())

    value = jnp.asarray(p, jnp.float32)
    bounds = band.copy()
    path: Tuple[str, ...] = (RFF_TIER,)
    if esc:
        dens = engine._dispatch(prep, y[np.flatnonzero(mask)], exact_tier)
        value = value.at[jnp.asarray(np.flatnonzero(mask))].set(
            jnp.asarray(dens, jnp.float32))
        bounds[mask] = exact_bound(exact_tier, cfg.prune)
        path = (RFF_TIER, exact_tier)

    obs.counter("serve.cascade_hits",
                "query rows answered at the RFF fast tier").inc(hits)
    if esc:
        obs.counter("serve.cascade_escalations",
                    "query rows escalated to the exact tier").inc(esc)
    hist = obs.histogram("serve.cascade_band",
                         "certified RFF band width per sampled query row",
                         lo=1e-6, hi=1e2)
    for b in band[:: max(1, m // _BAND_SAMPLE)]:
        hist.observe(max(float(b), 1e-6))
    return CascadeResult(value=value, bounds=bounds, hits=hits,
                         escalated=esc, path=path, esc_rows=mask)


__all__ = ["CascadeResult", "exact_bound", "engaged", "evaluate", "run"]
