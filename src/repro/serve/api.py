"""The typed query API: one request object, one answer object.

Before this module, a per-request SLO was smeared across knob soup —
``precision=`` here, ``deadline_s`` (absolute) on the plain engine,
``deadline_ms`` (relative) on the resilient one, ``accuracy_target`` only
at fit time, ``allow_degraded`` only on the resilient path.  The redesign
makes every per-request intent one :class:`QueryRequest` and every
outcome one :class:`Answer`, across ``ServeEngine.query``/``query_many``,
``AsyncFrontend.submit`` and ``ResilientEngine.query`` (the legacy
positional signatures survive one release as ``DeprecationWarning``
shims that return their legacy types).

**Precedence.**  The request object is the single authority for the
serving tier::

    request pin  >  explicit config  >  planner

``ServeConfig`` resolution already folds "explicit config beats planner"
at fit time (``plan/planner.resolve_config``), so the seam this module
closes is the per-request one: a ``precision`` pin on the request always
wins, and when it overrides a planner-chosen tier the engine counts it
(``serve.pin_overrides_plan``) instead of silently diverging from the
plan every dispatch span claims.

``precision="rff"`` pins the random-feature fast tier
(``kernels/flash_rff.py``); a request with an ``accuracy_target`` and no
pin enters the accuracy cascade (``serve/cascade.py``): answered at the
RFF tier when its certified band fits the target, escalated to the
pruned exact kernel otherwise.  ``Answer.path`` records the tiers
actually visited and ``Answer.rel_err_bounds`` the per-query certified
bound, whichever route answered.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.precision import PRECISIONS

#: The pinnable serving tiers: the exact GEMM-operand tiers plus the
#: random-feature fast tier.
RFF_TIER = "rff"
PINNABLE_TIERS = PRECISIONS + (RFF_TIER,)


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """Everything one request asks for, in one hashable-free object.

    ``deadline_s`` is *relative* seconds from submission — every layer
    converts to its own clock internally (this is the one deadline
    convention of the new API; the legacy shims keep their old ones).
    ``accuracy_target`` is the certified relative-error budget that
    drives cascade routing; ``None`` inherits the config's target (and
    disables the cascade when that is unset too).  ``precision`` pins a
    tier outright — exact tiers skip the cascade, ``"rff"`` forces the
    fast tier, band reported as-is.
    """

    key: str
    points: Any                              # (m, d) array-like
    accuracy_target: Optional[float] = None
    deadline_s: Optional[float] = None       # relative seconds
    precision: Optional[str] = None          # pin; one of PINNABLE_TIERS
    allow_degraded: Optional[bool] = None    # None = layer default

    def __post_init__(self):
        if not self.key:
            raise ValueError("QueryRequest.key must be a non-empty string")
        if self.precision is not None \
                and self.precision not in PINNABLE_TIERS:
            raise ValueError(
                f"unknown precision pin {self.precision!r} "
                f"(choose from {PINNABLE_TIERS})")
        if self.accuracy_target is not None \
                and not (self.accuracy_target > 0):
            raise ValueError(
                f"accuracy_target must be > 0, got {self.accuracy_target!r}")
        if self.deadline_s is not None and not (self.deadline_s > 0):
            raise ValueError(
                f"deadline_s is relative seconds and must be > 0, got "
                f"{self.deadline_s!r}")


@dataclasses.dataclass
class Answer:
    """One answer, whatever layer produced it.

    ``value`` is the density batch; ``tier`` the tier that answered the
    final rows and ``path`` every tier visited in order (``("rff",)``,
    ``("rff", "f32")``, ``("bf16",)``, ...).  ``rel_err_bound`` is the
    max certified relative-error bound over the batch and
    ``rel_err_bounds`` the per-query bounds (RFF band on fast-tier rows,
    tier rtol + prune epsilon on exact rows, missing-shard certificate
    on degraded rows).  The remaining fields carry each layer's
    provenance: admission (``state``/``queued_ms``/``browned``),
    resilience (``degraded``/shards/retries/hedges), streaming
    (``staleness`` generations behind live) and planning (``plan_id``).

    ``densities`` and ``precision`` are read-only compatibility views of
    ``value`` and ``tier`` for callers migrating off the legacy answer
    types (``FrontendAnswer``/``ResilientAnswer`` are aliases of this
    class).
    """

    value: jnp.ndarray
    key: str = ""
    tier: str = "f32"
    path: Tuple[str, ...] = ()
    rel_err_bound: float = 0.0
    rel_err_bounds: Optional[np.ndarray] = None
    rff_hits: int = 0                 # rows answered at the RFF tier
    escalated: int = 0                # rows escalated to an exact tier
    degraded: bool = False
    shed: bool = False
    browned: bool = False
    state: str = ""                   # admission state at dispatch
    staleness: int = 0                # generations behind live (streaming)
    plan_id: str = ""
    queued_ms: float = 0.0
    batch_requests: int = 1
    live_shards: Tuple[int, ...] = ()
    missing_shards: Tuple[int, ...] = ()
    retries: int = 0
    hedges: int = 0
    hedge_wins: int = 0
    latency_s: float = 0.0

    @property
    def densities(self) -> jnp.ndarray:
        return self.value

    @property
    def precision(self) -> str:
        return self.tier


def resolve_tier(pin: Optional[str], cfg_precision: str,
                 plan: object) -> Tuple[str, bool]:
    """Apply the precedence rule for one request.

    Returns ``(tier, pin_overrode_plan)``.  ``cfg_precision`` already
    encodes "explicit config beats planner" (fit-time resolution), so
    the only per-request decision left is the pin — and whether taking
    it diverges from a planner-chosen tier (the event the engine counts).
    """
    if pin is None:
        return cfg_precision, False
    overrode = (plan is not None
                and getattr(plan, "precision", None) is not None
                and getattr(plan, "precision") != pin)
    return pin, overrode


def warn_legacy(legacy: str, replacement: str) -> None:
    """The one-release deprecation shim warning (stacklevel: the caller
    of the public serve API, not the shim internals)."""
    warnings.warn(
        f"{legacy} is deprecated; {replacement} "
        f"(see docs/architecture.md, 'Query API & accuracy cascade')",
        DeprecationWarning, stacklevel=3)


__all__ = ["RFF_TIER", "PINNABLE_TIERS", "QueryRequest", "Answer",
           "resolve_tier", "warn_legacy"]
