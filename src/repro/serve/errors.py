"""Typed serve-layer errors.

Callers (and the CLI) need to tell "you asked for something that does not
exist" apart from "the service shed your request" apart from "the answer
is approximate" — three very different retry/alert policies.  Raw
``KeyError`` / shape ``ValueError`` cannot carry that distinction, so the
request path raises :class:`ServeError` subclasses instead.

``UnknownKey`` additionally subclasses ``KeyError`` so pre-existing
callers that guarded registry lookups with ``except KeyError`` keep
working.
"""

from __future__ import annotations


class ServeError(Exception):
    """Base class for every error raised by the serve request path."""


class UnknownKey(ServeError, KeyError):
    """No estimator fitted under the requested key."""

    def __str__(self) -> str:  # KeyError.__str__ repr-quotes; keep prose
        return Exception.__str__(self)


class BadRequest(ServeError, ValueError):
    """Malformed query: wrong dimensionality or an empty batch."""


class DeadlineExceeded(ServeError, TimeoutError):
    """The request's deadline expired before any replica answered."""


class Overloaded(ServeError):
    """The service shed the request instead of queueing it unboundedly.

    Raised by the resilient layer when no live replica can take a
    dispatch, and by the admission front end at admit time (queue full,
    token bucket empty, shedding, or draining) — ``reason`` carries the
    machine-readable shed cause so clients and the overload soak can
    split typed rejections by policy without parsing prose.
    """

    def __init__(self, msg: str, *, reason: str = "overload"):
        super().__init__(msg)
        self.reason = reason


class Degraded(ServeError):
    """A degraded (partial-shard) answer exists but its certified
    relative-error bound exceeds the configured accuracy target, and the
    caller did not opt into uncertified answers."""

    def __init__(self, msg: str, *, bound: float = float("inf"),
                 target: float = 0.0):
        super().__init__(msg)
        self.bound = bound
        self.target = target


__all__ = ["ServeError", "UnknownKey", "BadRequest", "DeadlineExceeded",
           "Overloaded", "Degraded"]
