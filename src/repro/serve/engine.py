"""The serving engine: registry + micro-batcher + backend dispatch.

Request lifecycle (see docs/architecture.md):

  register(key, x)         — one-time: debias (sdkde), precompute layouts,
                             cache, optionally fit the RFF fast tier
  query(QueryRequest)      — resolve the tier (request pin > explicit config
                             > planner), route through the accuracy cascade
                             when a target gates it, pad to a shape bucket,
                             run the bucket executable, return an Answer
                             with per-row certified bounds
  query_many([requests…])  — coalesce several ragged requests into ONE padded
                             dispatch, then split the fused Answer back out

Legacy ``query(key, y)`` / ``query_many(key, [y…])`` signatures still work
behind ``DeprecationWarning`` shims and return bare density arrays.

All three backends dispatch through the same bucket executables, built
lazily per (estimator, bucket) and kept in a small LRU:

  * ``jnp``    — streaming-GEMM reference (repro.core.kde), any hardware
  * ``pallas`` — prepared fast path (repro.kernels.ops.flash_kde_prepared):
                 train tensors transposed/normed once at fit, queries arrive
                 pre-padded so the per-call wrapper work disappears
  * ``ring``   — mesh-sharded evaluation (repro.distributed.ring) against
                 the fit-time sharded train placement
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import fault_injection, obs
from repro.serve import cascade
from repro.serve.api import (RFF_TIER, Answer, QueryRequest, resolve_tier,
                             warn_legacy)
from repro.serve.batching import ShapeBucketCache, coalesce, pad_queries, split
from repro.serve.config import ServeConfig
from repro.serve.errors import BadRequest, DeadlineExceeded
from repro.serve.registry import EstimatorRegistry, PreparedEstimator
from repro.serve.stats import LatencyRecorder


class ServeEngine:
    def __init__(
        self,
        config: ServeConfig | None = None,
        registry: EstimatorRegistry | None = None,
    ):
        if config is None:
            config = registry.config if registry is not None else ServeConfig()
        self.config = config
        self.registry = registry or EstimatorRegistry(config)
        self.cache = ShapeBucketCache(config.cache_buckets)
        self.latency = LatencyRecorder()
        # generations-behind-live of recent streaming dispatches (staleness
        # telemetry; a budget of 0 pins this to all-zeros).  Bounded so a
        # long-lived server doesn't grow it with request count.
        self.staleness_log: Deque[int] = deque(maxlen=8192)

    # -- fit path --------------------------------------------------------

    def register(
        self,
        key: str,
        x: jnp.ndarray,
        h: Optional[float] = None,
        config: ServeConfig | None = None,
        refit: bool = False,
        prewarm: Optional[bool] = None,
    ) -> PreparedEstimator:
        """Fit (or fetch) an estimator.  ``prewarm=None`` follows the
        resolved execution plan: plan-routed estimators build their
        chosen bucket executable at register time so the first real
        request never pays the compile; explicitly pass False to defer."""
        prep = self.registry.fit(key, x, h, config=config, refit=refit)
        if refit:
            self.cache.invalidate(lambda k: k[0] == key)
        if prewarm is None:
            prewarm = prep.plan is not None and getattr(
                prep.plan, "prewarm", False)
        if prewarm:
            self.prewarm(key)
        return prep

    def prewarm(self, key: str, all_buckets: bool = False) -> int:
        """Build bucket executables ahead of traffic through the normal
        LRU path (so prewarmed programs are the very ones requests hit).

        Default warms the largest bucket — the one every oversize batch
        chunks at; ``all_buckets`` walks the whole ladder.  Returns the
        number of buckets warmed.  Prewarm dispatches are not recorded as
        served latency."""
        prep = self.registry.get(key)
        cfg = prep.config
        tier = cfg.exact_precision
        sizes = cfg.bucket_sizes(prep.ring_size, prep.block_m)
        targets = sizes if all_buckets else sizes[-1:]
        with obs.span("plan.prewarm", key=key, buckets=len(targets),
                      plan=getattr(prep.plan, "plan_id", "")):
            for bucket in targets:
                snap = (prep.stream.ensure(cfg.staleness_budget)
                        if prep.stream is not None else None)
                y = jnp.zeros((bucket, prep.d), jnp.float32)
                jax.block_until_ready(
                    self._run_bucket(prep, y, tier, snap))
        obs.counter("plan.prewarms",
                    "bucket executables built ahead of traffic",
                    ).inc(len(targets))
        return len(targets)

    # -- query path ------------------------------------------------------

    def query(self, request: Union[QueryRequest, str],
              y: Optional[jnp.ndarray] = None,
              precision: Optional[str] = None,
              deadline_s: Optional[float] = None,
              ) -> Union[Answer, jnp.ndarray]:
        """Serve one request.

        Typed API: pass a :class:`~repro.serve.api.QueryRequest`, receive
        an :class:`~repro.serve.api.Answer`.  This is the only path that
        routes through the accuracy cascade — a request carrying an
        ``accuracy_target`` (or a config-level default) first runs the
        RFF fast tier and escalates only the rows whose certified band
        misses the target; ``request.precision`` pins a tier outright
        (precedence: request pin > explicit config > planner).  The
        request's ``deadline_s`` is *relative* seconds from admission.

        Legacy API (deprecated): ``query(key, y, precision=, deadline_s=)``
        returns the bare densities array; its ``deadline_s`` is an
        absolute ``time.monotonic()`` instant, and it never engages the
        cascade (unless the config's default tier itself is ``"rff"``),
        exactly as before the typed API existed.

        Either way, a request past its deadline raises
        ``DeadlineExceeded`` before any compute, and an answer that
        completes past it raises too — a late density is not an answer.
        """
        if isinstance(request, QueryRequest):
            if y is not None or precision is not None \
                    or deadline_s is not None:
                raise BadRequest(
                    "pass either a QueryRequest or the legacy "
                    "(key, y, ...) arguments, not both")
            return self._query_request(request)
        warn_legacy("ServeEngine.query(key, y, ...)",
                    "ServeEngine.query(QueryRequest(...)) -> Answer")
        req = QueryRequest(key=request, points=y, precision=precision)
        ans = self._query_request(req, deadline_abs=deadline_s, legacy=True)
        return ans.value

    def query_many(
        self,
        requests: Union[Sequence[QueryRequest], str],
        batches: Optional[Sequence[jnp.ndarray]] = None,
        precision: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> Union[List[Answer], List[jnp.ndarray]]:
        """Coalesce several ragged requests into one padded dispatch.

        Typed API: a non-empty sequence of :class:`QueryRequest` sharing
        one key and one precision pin (coalesce upstream per
        ``(key, precision)`` — the async front end already does) returns
        one :class:`Answer` per request, each carrying its own slice of
        the fused per-row certified bounds and cascade counters.
        Members' ``accuracy_target`` may differ: the cascade gates row
        ranges independently, and members without a target (and no
        config default) always resolve at the exact tier.  The fused
        dispatch runs under the *latest* member deadline — per-member
        lateness is the upstream batcher's call.

        Legacy API (deprecated): ``query_many(key, batches, ...)`` with
        an absolute monotonic ``deadline_s`` returns bare density arrays.
        """
        if batches is None and not isinstance(requests, str):
            reqs = list(requests)
            if not reqs or not all(isinstance(r, QueryRequest)
                                   for r in reqs):
                raise BadRequest(
                    "query_many takes a non-empty sequence of QueryRequest "
                    "(or the legacy key + batches arguments)")
            key, pin = reqs[0].key, reqs[0].precision
            for r in reqs[1:]:
                if r.key != key or r.precision != pin:
                    raise BadRequest(
                        "fused query_many requests must share one key and "
                        "one precision pin — coalesce upstream per "
                        "(key, precision)")
            prep = self.registry.get(key)
            fused, sizes = coalesce([
                jnp.atleast_2d(jnp.asarray(r.points, jnp.float32))
                for r in reqs])
            self._check_query(prep, fused)
            now = time.monotonic()
            member_dl = [now + r.deadline_s for r in reqs
                         if r.deadline_s is not None]
            fused_dl = max(member_dl) if member_dl else None
            self._check_deadline(key, fused_dl, phase="dispatch")
            with obs.span("serve.request", key=key,
                          rows=int(fused.shape[0]), requests=len(sizes)):
                t0 = time.perf_counter()
                ans, esc_rows = self._serve(prep, fused, reqs, sizes)
                ans.value = jax.block_until_ready(fault_injection.poison(
                    "serve.result", ans.value))
                dt = time.perf_counter() - t0
            self._check_deadline(key, fused_dl, phase="answer")
            self._note_served(dt, fused.shape[0], len(sizes))
            return self._split_answer(ans, reqs, sizes, esc_rows, dt)
        warn_legacy(
            "ServeEngine.query_many(key, batches, ...)",
            "ServeEngine.query_many([QueryRequest, ...]) -> [Answer, ...]")
        key = requests
        reqs = [QueryRequest(key=key, points=b, precision=precision)
                for b in batches]
        prep = self.registry.get(key)
        fused, sizes = coalesce([
            jnp.atleast_2d(jnp.asarray(b, jnp.float32)) for b in batches])
        self._check_query(prep, fused)
        self._check_deadline(key, deadline_s, phase="dispatch")
        with obs.span("serve.request", key=key, rows=int(fused.shape[0]),
                      requests=len(sizes)):
            t0 = time.perf_counter()
            ans, _ = self._serve(prep, fused, reqs, sizes, legacy=True)
            dens = jax.block_until_ready(fault_injection.poison(
                "serve.result", ans.value))
            dt = time.perf_counter() - t0
        self._check_deadline(key, deadline_s, phase="answer")
        self._note_served(dt, fused.shape[0], len(sizes))
        return split(dens, sizes)

    def _query_request(self, req: QueryRequest, *,
                       deadline_abs: Optional[float] = None,
                       legacy: bool = False) -> Answer:
        prep = self.registry.get(req.key)
        y = jnp.atleast_2d(jnp.asarray(req.points, jnp.float32))
        self._check_query(prep, y)
        if deadline_abs is None and req.deadline_s is not None:
            deadline_abs = time.monotonic() + req.deadline_s
        self._check_deadline(req.key, deadline_abs, phase="dispatch")
        with obs.span("serve.request", key=req.key, rows=int(y.shape[0]),
                      requests=1):
            t0 = time.perf_counter()
            ans, _ = self._serve(prep, y, [req], [int(y.shape[0])],
                                 legacy=legacy)
            ans.value = jax.block_until_ready(fault_injection.poison(
                "serve.result", ans.value))
            dt = time.perf_counter() - t0
        self._check_deadline(req.key, deadline_abs, phase="answer")
        self._note_served(dt, y.shape[0], 1)
        ans.latency_s = dt
        return ans

    def _serve(self, prep: PreparedEstimator, y: jnp.ndarray,
               reqs: Sequence[QueryRequest], sizes: Sequence[int], *,
               legacy: bool = False):
        """Resolve the tier, route through the cascade when engaged, and
        assemble one fused :class:`Answer` for ``y`` (per-request slicing
        is the caller's job).  Returns ``(answer, esc_rows)`` where
        ``esc_rows`` marks the fused rows that escalated."""
        cfg = prep.config
        tier, overrode = resolve_tier(reqs[0].precision, cfg.precision,
                                      prep.plan)
        if overrode:
            obs.counter(
                "serve.pin_overrides_plan",
                "requests whose precision pin overrode the planner tier",
            ).inc()
        m = int(y.shape[0])
        target = None if legacy else self._targets(cfg, reqs, sizes)
        snap = (prep.stream.ensure(cfg.staleness_budget)
                if prep.stream is not None else None)
        res = None
        # an explicit exact-tier pin skips the fast tier entirely — the
        # pin IS the routing decision; only unpinned requests (or an
        # "rff" pin) consult the cascade gate
        if tier == RFF_TIER or (not legacy and reqs[0].precision is None
                                and cascade.engaged(cfg, prep, tier, target)):
            res = cascade.run(self, prep, y, tier, target, snap=snap)
            if res is None and tier == RFF_TIER:
                raise BadRequest(
                    f"precision='rff' pinned but the RFF tier is "
                    f"unavailable for method={cfg.method!r} "
                    f"backend={cfg.backend!r} (rff={cfg.rff!r})")
        if res is not None:
            value, bounds = res.value, res.bounds
            hits, esc, path = res.hits, res.escalated, res.path
            esc_rows = res.esc_rows
        else:
            exact = cfg.exact_precision if tier == RFF_TIER else tier
            value = self._dispatch(prep, y, exact)
            bounds = np.full(m, cascade.exact_bound(exact, cfg.prune))
            hits, esc, path = 0, 0, (exact,)
            esc_rows = np.zeros(m, bool)
        staleness = (prep.stream.gen - snap.gen) if snap is not None else 0
        ans = Answer(
            value=value, key=prep.key, tier=path[-1], path=path,
            rel_err_bound=float(bounds.max()) if m else 0.0,
            rel_err_bounds=bounds, rff_hits=hits, escalated=esc,
            staleness=staleness,
            plan_id=getattr(prep.plan, "plan_id", "") or "",
        )
        return ans, esc_rows

    @staticmethod
    def _targets(cfg: ServeConfig, reqs: Sequence[QueryRequest],
                 sizes: Sequence[int]):
        """Per-row accuracy-target vector for a fused batch, or None when
        no member carries one.  A request target beats the config
        default; a member with neither gets ``-inf`` so its rows always
        escalate — an untargeted request expects an exact-grade answer
        even when fused with cascade-routed neighbors."""
        per = [r.accuracy_target if r.accuracy_target is not None
               else cfg.accuracy_target for r in reqs]
        if all(t is None for t in per):
            return None
        out = np.empty(int(sum(sizes)))
        off = 0
        for t, s in zip(per, sizes):
            out[off:off + s] = -np.inf if t is None else float(t)
            off += s
        return out

    @staticmethod
    def _split_answer(ans: Answer, reqs: Sequence[QueryRequest],
                      sizes: Sequence[int], esc_rows: np.ndarray,
                      dt: float) -> List[Answer]:
        parts = split(ans.value, sizes)
        offs = np.cumsum([0] + list(sizes))
        cascaded = RFF_TIER in ans.path
        out = []
        for i, dens in enumerate(parts):
            lo, hi = int(offs[i]), int(offs[i + 1])
            rows = hi - lo
            b = ans.rel_err_bounds[lo:hi]
            esc = int(esc_rows[lo:hi].sum()) if cascaded else 0
            hits = rows - esc if cascaded else 0
            path = (RFF_TIER,) if cascaded and not esc else ans.path
            out.append(Answer(
                value=dens, key=ans.key, tier=path[-1], path=path,
                rel_err_bound=float(b.max()) if rows else 0.0,
                rel_err_bounds=b, rff_hits=hits, escalated=esc,
                staleness=ans.staleness, plan_id=ans.plan_id,
                latency_s=dt, batch_requests=len(reqs)))
        return out

    @staticmethod
    def _check_query(prep: PreparedEstimator, y: jnp.ndarray) -> None:
        if y.ndim != 2 or y.shape[0] == 0 or y.shape[-1] != prep.d:
            raise BadRequest(
                f"query shape {tuple(y.shape)} does not match estimator "
                f"{prep.key!r} (expected (m, {prep.d}) with m >= 1)"
            )

    @staticmethod
    def _check_deadline(key: str, deadline_s: Optional[float],
                        phase: str) -> None:
        if deadline_s is None:
            return
        late = time.monotonic() - deadline_s
        if late >= 0:
            obs.counter("serve.deadline_exceeded",
                        "requests past their deadline at the plain engine",
                        labels={"phase": phase}).inc()
            raise DeadlineExceeded(
                f"request for {key!r} missed its deadline by "
                f"{1e3 * late:.1f}ms "
                + ("before dispatch" if phase == "dispatch"
                   else "(answer completed late)")
            )

    def _note_served(self, seconds: float, rows: int, requests: int) -> None:
        self.latency.record(seconds, rows, requests)
        obs.counter("serve.requests", "requests admitted").inc(requests)
        obs.counter("serve.queries", "density rows served").inc(rows)

    # -- telemetry --------------------------------------------------------

    def metrics(self) -> dict:
        """One JSON-safe view of everything this engine can observe:
        per-engine latency (bounded histogram), bucket-cache efficiency,
        streaming staleness, and the process-wide obs registry (kernel
        prune occupancy, autotune decisions, stream gauges, ...)."""
        return {
            "latency": self.latency.summary().as_dict(),
            "latency_hist": self.latency.histogram_snapshot(),
            "bucket_cache": {
                "hits": self.cache.hits,
                "misses": self.cache.misses,
                "evictions": self.cache.evictions,
                "resident": len(self.cache),
            },
            "staleness": self.staleness_summary(),
            "registry": obs.metrics_snapshot(),
        }

    def trace_events(self) -> list:
        """The buffered obs span events (enable with
        ``obs.configure(trace=True)``)."""
        return obs.trace_events()

    # -- streaming telemetry ---------------------------------------------

    def staleness_summary(self) -> dict:
        """p50/p99/max of how many generations behind live each streaming
        dispatch was served (empty dict when nothing streamed)."""
        if not self.staleness_log:
            return {}
        xs = sorted(self.staleness_log)

        def pct(q):
            return xs[min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))]

        return {"count": len(xs), "p50": pct(0.5), "p99": pct(0.99),
                "max": xs[-1]}

    # -- internals -------------------------------------------------------

    def _dispatch(self, prep: PreparedEstimator, y: jnp.ndarray,
                  precision: Optional[str] = None) -> jnp.ndarray:
        cfg = prep.config
        # _dispatch is the *exact* dispatcher — the RFF fast tier routes
        # through serve/cascade.py, which calls back here only for
        # escalated rows at the escalation tier
        tier = precision or cfg.exact_precision
        if tier == RFF_TIER:
            raise BadRequest("the RFF tier has no exact dispatch path")
        snap = None
        sp = obs.span("serve.dispatch", key=prep.key, backend=cfg.backend,
                      tier=tier, rows=int(y.shape[0]))
        with sp:
            # chaos hook: a killed replica raises InjectedFailure here, a
            # slow one sleeps — before any compute, like a dead device
            fault_injection.fire("serve.dispatch", key=prep.key)
            if prep.plan is not None:
                # every served request traces back to the plan that
                # shaped its execution
                sp.set(plan=prep.plan.plan_id)
            if prep.stream is not None:
                # the staleness gate: get a snapshot at most ``staleness_
                # budget`` generations behind live (waiting for /
                # performing a flush only past the budget), then pin the
                # whole dispatch to it — concurrent appends/evictions
                # publish NEW snapshots and can never mutate the one in
                # flight
                snap = prep.stream.ensure(cfg.staleness_budget)
                lag = prep.stream.gen - snap.gen
                self.staleness_log.append(lag)
                obs.histogram("serve.staleness_gen",
                              "generations behind live per streaming "
                              "dispatch", lo=1, hi=1e4,
                              per_decade=8).observe(lag)
                sp.set(staleness=lag, stream_gen=snap.gen,
                       layout_epoch=snap.layout_epoch)
            top = cfg.bucket_sizes(prep.ring_size, prep.block_m)[-1]
            m = y.shape[0]
            if m <= top:
                return self._run_bucket(prep, y, tier, snap)
            # oversize batch: chunk at the largest bucket (each chunk
            # jit-stable)
            sp.set(chunks=-(-m // top))
            parts = [
                self._run_bucket(prep, y[off:off + top], tier, snap)
                for off in range(0, m, top)
            ]
            return jnp.concatenate(parts)

    def _run_bucket(self, prep: PreparedEstimator, y: jnp.ndarray,
                    tier: str, snap=None):
        cfg = prep.config
        m = y.shape[0]
        bucket = cfg.bucket_for(m, prep.ring_size, prep.block_m)
        if prep.stream is not None:
            # Streaming executables read train tensors from the pinned
            # snapshot per call, so value-only generation bumps reuse the
            # compiled program untouched; the layout epoch joins the key
            # because only a rebuild changes the column *shapes* — that is
            # the one event that actually invalidates an executable.
            ck = (prep.key, prep.generation, "stream", snap.layout_epoch,
                  tier, bucket)
            build = lambda: self._build_stream_executable(prep, tier)  # noqa: E731
        else:
            # Keyed on the fit generation: a refit (or evict + re-register)
            # produces a new generation, so stale executables can never
            # serve it.  The tier is part of the key — each precision gets
            # its own bucket executable against its own prepared train
            # tensors.
            ck = (prep.key, prep.generation, tier, bucket)
            build = lambda: self._build_executable(prep, tier)  # noqa: E731
        hit = ck in self.cache
        obs.histogram("serve.pad_ratio",
                      "bucket rows / real rows per dispatch",
                      lo=1.0, hi=1e4, per_decade=12).observe(bucket / m)
        with obs.span("serve.bucket", key=prep.key, bucket=bucket, rows=m,
                      pad_ratio=round(bucket / m, 4),
                      cache="hit" if hit else "miss"):
            fn = self.cache.get_or_build(
                ck, lambda: self._timed_build(build, prep, bucket)
            )
            if prep.stream is not None:
                return fn(pad_queries(y, bucket), m, snap)[:m]
            return fn(pad_queries(y, bucket), m)[:m]

    def _timed_build(self, build, prep: PreparedEstimator, bucket: int):
        """Build a bucket executable under a compile span + histogram, so
        a recompile storm is visible as `serve.compile_s` mass."""
        t0 = time.perf_counter()
        with obs.span("serve.compile", key=prep.key, bucket=bucket):
            fault_injection.fire("serve.compile", key=prep.key)
            fn = build()
        obs.histogram("serve.compile_s", "bucket-executable build seconds",
                      lo=1e-5, hi=1e3).observe(time.perf_counter() - t0)
        return fn

    def _build_stream_executable(self, prep: PreparedEstimator, tier: str):
        """Bucket executable for a streaming estimator: fn(yp, n_real, snap).

        Unlike the static path, no train tensor is closed over — each call
        reads the snapshot its dispatch is pinned to.  Normalization uses
        the snapshot's live count (appends/evictions move it), and the
        prune decision re-resolves per call because the live count drifts
        across the auto threshold as points come and go.
        """
        cfg = prep.config
        laplace = cfg.method == "laplace"

        if cfg.backend == "pallas":
            from repro.kernels import ops

            jfn = jax.jit(lambda yp, xt, nrm_x, xt_lo: ops.flash_kde_prepared(
                yp, xt, nrm_x, prep.h, xt_lo,
                precision=tier,
                block_m=prep.block_m, block_n=prep.block_n,
                interpret=cfg.interpret, laplace=laplace,
            ))

            def fn(yp, n_real, snap):
                cols = prep.stream.columns_for(tier, snap)
                eps = ops.resolve_prune(cfg.prune, snap.n_live,
                                        prep.block_n)
                if eps is not None and cols.meta is not None:
                    sums = ops.flash_kde_prepared(
                        yp, cols.xt, cols.nrm_x, prep.h, cols.xt_lo,
                        precision=tier,
                        block_m=prep.block_m, block_n=prep.block_n,
                        interpret=cfg.interpret, laplace=laplace,
                        prune=cfg.prune, columns=cols, n_real=n_real,
                    )
                else:
                    sums = jfn(yp, cols.xt, cols.nrm_x, cols.xt_lo)
                return sums / snap.norm

            return fn

        from repro.core import kde as ref

        eval_fn = ref.laplace_kde_eval if laplace else ref.kde_eval
        jfn = jax.jit(
            lambda yp, pts: eval_fn(pts, yp, prep.h, block=cfg.block)
        )
        # snap.xp is the live set padded to a pow2 row bucket (bounded
        # retraces across generations); sentinel rows contribute exactly
        # 0.0 to the sums but inflate eval_fn's 1/n normalization, so
        # rescale padded-n back to the live count
        return lambda yp, n_real, snap: jfn(yp, snap.xp) * (
            snap.xp.shape[0] / snap.n_live
        )

    def _build_executable(self, prep: PreparedEstimator, tier: str):
        """Bucket executable: padded (bucket, d) queries → (bucket,) dens.

        The executable signature is ``fn(yp, n_real)`` — ``n_real`` is the
        true (pre-padding) query count; the pruned pallas path needs it to
        keep sentinel rows out of the row-tile geometry, every other
        backend ignores it.

        Each executable owns its jit wrapper (train tensors passed as
        arguments, not baked as constants), so evicting an entry from the
        LRU releases its compiled program — the cache bounds compilations,
        not just Python closures.
        """
        cfg = prep.config
        laplace = cfg.method == "laplace"

        if cfg.backend == "pallas":
            from repro.kernels import ops

            cols = prep.columns_for(tier)
            # decide pruning ONCE per executable: "auto" below the size
            # threshold means every request takes the plain jitted dense
            # path — no per-request python dispatch overhead
            eps = ops.resolve_prune(cfg.prune, prep.n_true, prep.block_n)
            if eps is not None and cols.meta is not None:
                # Pruned path: not a single jit program — the per-batch
                # bounds prepass host-syncs to compact visit lists, and
                # flash_kde_prepared jit-caches the kernel per bucketed
                # visit extent underneath.
                def pruned_fn(yp, n_real):
                    sums = ops.flash_kde_prepared(
                        yp, cols.xt, cols.nrm_x, prep.h, cols.xt_lo,
                        precision=tier,
                        block_m=prep.block_m, block_n=prep.block_n,
                        interpret=cfg.interpret, laplace=laplace,
                        prune=cfg.prune, columns=cols, n_real=n_real,
                    )
                    return sums / prep.norm

                return pruned_fn
            jfn = jax.jit(lambda yp, xt, nrm_x, xt_lo: ops.flash_kde_prepared(
                yp, xt, nrm_x, prep.h, xt_lo,
                precision=tier,
                block_m=prep.block_m, block_n=prep.block_n,
                interpret=cfg.interpret, laplace=laplace,
            ) / prep.norm)
            return lambda yp, n_real: jfn(yp, cols.xt, cols.nrm_x,
                                          cols.xt_lo)

        if cfg.backend == "ring":
            from repro.distributed import ring

            eval_fn = ring.ring_laplace_kde if laplace else ring.ring_kde
            jfn = jax.jit(lambda yp, xs: eval_fn(
                xs, yp, prep.h, n_true=prep.n_true, mesh=prep.mesh,
            ))
            return lambda yp, n_real: jfn(yp, prep.x_sharded)

        from repro.core import kde as ref

        eval_fn = ref.laplace_kde_eval if laplace else ref.kde_eval
        jfn = jax.jit(
            lambda yp, pts: eval_fn(pts, yp, prep.h, block=cfg.block)
        )
        return lambda yp, n_real: jfn(yp, prep.points)


__all__ = ["ServeEngine"]
