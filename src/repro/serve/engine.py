"""The serving engine: registry + micro-batcher + backend dispatch.

Request lifecycle (see docs/architecture.md):

  register(key, x)      — one-time: debias (sdkde), precompute layouts, cache
  query(key, y)         — pad y to a shape bucket, run the bucket executable,
                          slice, record latency
  query_many(key, [y…]) — coalesce several ragged requests into ONE padded
                          dispatch, then split the fused densities back out

All three backends dispatch through the same bucket executables, built
lazily per (estimator, bucket) and kept in a small LRU:

  * ``jnp``    — streaming-GEMM reference (repro.core.kde), any hardware
  * ``pallas`` — prepared fast path (repro.kernels.ops.flash_kde_prepared):
                 train tensors transposed/normed once at fit, queries arrive
                 pre-padded so the per-call wrapper work disappears
  * ``ring``   — mesh-sharded evaluation (repro.distributed.ring) against
                 the fit-time sharded train placement
"""

from __future__ import annotations

import time
from collections import deque
from typing import Deque, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import fault_injection, obs
from repro.serve.batching import ShapeBucketCache, coalesce, pad_queries, split
from repro.serve.config import ServeConfig
from repro.serve.errors import BadRequest, DeadlineExceeded
from repro.serve.registry import EstimatorRegistry, PreparedEstimator
from repro.serve.stats import LatencyRecorder


class ServeEngine:
    def __init__(
        self,
        config: ServeConfig | None = None,
        registry: EstimatorRegistry | None = None,
    ):
        if config is None:
            config = registry.config if registry is not None else ServeConfig()
        self.config = config
        self.registry = registry or EstimatorRegistry(config)
        self.cache = ShapeBucketCache(config.cache_buckets)
        self.latency = LatencyRecorder()
        # generations-behind-live of recent streaming dispatches (staleness
        # telemetry; a budget of 0 pins this to all-zeros).  Bounded so a
        # long-lived server doesn't grow it with request count.
        self.staleness_log: Deque[int] = deque(maxlen=8192)

    # -- fit path --------------------------------------------------------

    def register(
        self,
        key: str,
        x: jnp.ndarray,
        h: Optional[float] = None,
        config: ServeConfig | None = None,
        refit: bool = False,
        prewarm: Optional[bool] = None,
    ) -> PreparedEstimator:
        """Fit (or fetch) an estimator.  ``prewarm=None`` follows the
        resolved execution plan: plan-routed estimators build their
        chosen bucket executable at register time so the first real
        request never pays the compile; explicitly pass False to defer."""
        prep = self.registry.fit(key, x, h, config=config, refit=refit)
        if refit:
            self.cache.invalidate(lambda k: k[0] == key)
        if prewarm is None:
            prewarm = prep.plan is not None and getattr(
                prep.plan, "prewarm", False)
        if prewarm:
            self.prewarm(key)
        return prep

    def prewarm(self, key: str, all_buckets: bool = False) -> int:
        """Build bucket executables ahead of traffic through the normal
        LRU path (so prewarmed programs are the very ones requests hit).

        Default warms the largest bucket — the one every oversize batch
        chunks at; ``all_buckets`` walks the whole ladder.  Returns the
        number of buckets warmed.  Prewarm dispatches are not recorded as
        served latency."""
        prep = self.registry.get(key)
        cfg = prep.config
        tier = cfg.precision
        sizes = cfg.bucket_sizes(prep.ring_size, prep.block_m)
        targets = sizes if all_buckets else sizes[-1:]
        with obs.span("plan.prewarm", key=key, buckets=len(targets),
                      plan=getattr(prep.plan, "plan_id", "")):
            for bucket in targets:
                snap = (prep.stream.ensure(cfg.staleness_budget)
                        if prep.stream is not None else None)
                y = jnp.zeros((bucket, prep.d), jnp.float32)
                jax.block_until_ready(
                    self._run_bucket(prep, y, tier, snap))
        obs.counter("plan.prewarms",
                    "bucket executables built ahead of traffic",
                    ).inc(len(targets))
        return len(targets)

    # -- query path ------------------------------------------------------

    def query(self, key: str, y: jnp.ndarray,
              precision: Optional[str] = None,
              deadline_s: Optional[float] = None) -> jnp.ndarray:
        """Densities for one request; pads to a bucket, times the dispatch.

        ``precision`` overrides the config's GEMM-operand tier for this
        request (pallas backend; prepared train tensors are cached per
        tier, so mixing tiers on one estimator costs one extra prepare).

        ``deadline_s`` is an absolute ``time.monotonic()`` instant: a
        request whose deadline has already passed raises
        ``DeadlineExceeded`` before any compute, and an answer that
        completes past it raises too — a late density is not an answer
        (the admission front end propagates its per-request deadlines
        here, so plain engines honor them like ``ResilientEngine`` does).
        """
        prep = self.registry.get(key)
        y = jnp.atleast_2d(jnp.asarray(y, jnp.float32))
        self._check_query(prep, y)
        self._check_deadline(key, deadline_s, phase="dispatch")
        with obs.span("serve.request", key=key, rows=int(y.shape[0]),
                      requests=1):
            t0 = time.perf_counter()
            dens = jax.block_until_ready(fault_injection.poison(
                "serve.result", self._dispatch(prep, y, precision)))
            dt = time.perf_counter() - t0
        self._check_deadline(key, deadline_s, phase="answer")
        self._note_served(dt, y.shape[0], 1)
        return dens

    def query_many(
        self, key: str, batches: Sequence[jnp.ndarray],
        precision: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> List[jnp.ndarray]:
        """Coalesce several ragged requests into one padded dispatch.

        ``deadline_s`` (absolute monotonic) covers the fused dispatch:
        callers batching requests with distinct deadlines should pass the
        *latest* one and re-check the earlier deadlines per member.
        """
        prep = self.registry.get(key)
        fused, sizes = coalesce(batches)
        self._check_query(prep, fused)
        self._check_deadline(key, deadline_s, phase="dispatch")
        with obs.span("serve.request", key=key, rows=int(fused.shape[0]),
                      requests=len(sizes)):
            t0 = time.perf_counter()
            dens = jax.block_until_ready(fault_injection.poison(
                "serve.result", self._dispatch(prep, fused, precision)))
            dt = time.perf_counter() - t0
        self._check_deadline(key, deadline_s, phase="answer")
        self._note_served(dt, fused.shape[0], len(sizes))
        return split(dens, sizes)

    @staticmethod
    def _check_query(prep: PreparedEstimator, y: jnp.ndarray) -> None:
        if y.ndim != 2 or y.shape[0] == 0 or y.shape[-1] != prep.d:
            raise BadRequest(
                f"query shape {tuple(y.shape)} does not match estimator "
                f"{prep.key!r} (expected (m, {prep.d}) with m >= 1)"
            )

    @staticmethod
    def _check_deadline(key: str, deadline_s: Optional[float],
                        phase: str) -> None:
        if deadline_s is None:
            return
        late = time.monotonic() - deadline_s
        if late >= 0:
            obs.counter("serve.deadline_exceeded",
                        "requests past their deadline at the plain engine",
                        labels={"phase": phase}).inc()
            raise DeadlineExceeded(
                f"request for {key!r} missed its deadline by "
                f"{1e3 * late:.1f}ms "
                + ("before dispatch" if phase == "dispatch"
                   else "(answer completed late)")
            )

    def _note_served(self, seconds: float, rows: int, requests: int) -> None:
        self.latency.record(seconds, rows, requests)
        obs.counter("serve.requests", "requests admitted").inc(requests)
        obs.counter("serve.queries", "density rows served").inc(rows)

    # -- telemetry --------------------------------------------------------

    def metrics(self) -> dict:
        """One JSON-safe view of everything this engine can observe:
        per-engine latency (bounded histogram), bucket-cache efficiency,
        streaming staleness, and the process-wide obs registry (kernel
        prune occupancy, autotune decisions, stream gauges, ...)."""
        return {
            "latency": self.latency.summary().as_dict(),
            "latency_hist": self.latency.histogram_snapshot(),
            "bucket_cache": {
                "hits": self.cache.hits,
                "misses": self.cache.misses,
                "evictions": self.cache.evictions,
                "resident": len(self.cache),
            },
            "staleness": self.staleness_summary(),
            "registry": obs.metrics_snapshot(),
        }

    def trace_events(self) -> list:
        """The buffered obs span events (enable with
        ``obs.configure(trace=True)``)."""
        return obs.trace_events()

    # -- streaming telemetry ---------------------------------------------

    def staleness_summary(self) -> dict:
        """p50/p99/max of how many generations behind live each streaming
        dispatch was served (empty dict when nothing streamed)."""
        if not self.staleness_log:
            return {}
        xs = sorted(self.staleness_log)

        def pct(q):
            return xs[min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))]

        return {"count": len(xs), "p50": pct(0.5), "p99": pct(0.99),
                "max": xs[-1]}

    # -- internals -------------------------------------------------------

    def _dispatch(self, prep: PreparedEstimator, y: jnp.ndarray,
                  precision: Optional[str] = None) -> jnp.ndarray:
        cfg = prep.config
        tier = precision or cfg.precision
        snap = None
        sp = obs.span("serve.dispatch", key=prep.key, backend=cfg.backend,
                      tier=tier, rows=int(y.shape[0]))
        with sp:
            # chaos hook: a killed replica raises InjectedFailure here, a
            # slow one sleeps — before any compute, like a dead device
            fault_injection.fire("serve.dispatch", key=prep.key)
            if prep.plan is not None:
                # every served request traces back to the plan that
                # shaped its execution
                sp.set(plan=prep.plan.plan_id)
            if prep.stream is not None:
                # the staleness gate: get a snapshot at most ``staleness_
                # budget`` generations behind live (waiting for /
                # performing a flush only past the budget), then pin the
                # whole dispatch to it — concurrent appends/evictions
                # publish NEW snapshots and can never mutate the one in
                # flight
                snap = prep.stream.ensure(cfg.staleness_budget)
                lag = prep.stream.gen - snap.gen
                self.staleness_log.append(lag)
                obs.histogram("serve.staleness_gen",
                              "generations behind live per streaming "
                              "dispatch", lo=1, hi=1e4,
                              per_decade=8).observe(lag)
                sp.set(staleness=lag, stream_gen=snap.gen,
                       layout_epoch=snap.layout_epoch)
            top = cfg.bucket_sizes(prep.ring_size, prep.block_m)[-1]
            m = y.shape[0]
            if m <= top:
                return self._run_bucket(prep, y, tier, snap)
            # oversize batch: chunk at the largest bucket (each chunk
            # jit-stable)
            sp.set(chunks=-(-m // top))
            parts = [
                self._run_bucket(prep, y[off:off + top], tier, snap)
                for off in range(0, m, top)
            ]
            return jnp.concatenate(parts)

    def _run_bucket(self, prep: PreparedEstimator, y: jnp.ndarray,
                    tier: str, snap=None):
        cfg = prep.config
        m = y.shape[0]
        bucket = cfg.bucket_for(m, prep.ring_size, prep.block_m)
        if prep.stream is not None:
            # Streaming executables read train tensors from the pinned
            # snapshot per call, so value-only generation bumps reuse the
            # compiled program untouched; the layout epoch joins the key
            # because only a rebuild changes the column *shapes* — that is
            # the one event that actually invalidates an executable.
            ck = (prep.key, prep.generation, "stream", snap.layout_epoch,
                  tier, bucket)
            build = lambda: self._build_stream_executable(prep, tier)  # noqa: E731
        else:
            # Keyed on the fit generation: a refit (or evict + re-register)
            # produces a new generation, so stale executables can never
            # serve it.  The tier is part of the key — each precision gets
            # its own bucket executable against its own prepared train
            # tensors.
            ck = (prep.key, prep.generation, tier, bucket)
            build = lambda: self._build_executable(prep, tier)  # noqa: E731
        hit = ck in self.cache
        obs.histogram("serve.pad_ratio",
                      "bucket rows / real rows per dispatch",
                      lo=1.0, hi=1e4, per_decade=12).observe(bucket / m)
        with obs.span("serve.bucket", key=prep.key, bucket=bucket, rows=m,
                      pad_ratio=round(bucket / m, 4),
                      cache="hit" if hit else "miss"):
            fn = self.cache.get_or_build(
                ck, lambda: self._timed_build(build, prep, bucket)
            )
            if prep.stream is not None:
                return fn(pad_queries(y, bucket), m, snap)[:m]
            return fn(pad_queries(y, bucket), m)[:m]

    def _timed_build(self, build, prep: PreparedEstimator, bucket: int):
        """Build a bucket executable under a compile span + histogram, so
        a recompile storm is visible as `serve.compile_s` mass."""
        t0 = time.perf_counter()
        with obs.span("serve.compile", key=prep.key, bucket=bucket):
            fault_injection.fire("serve.compile", key=prep.key)
            fn = build()
        obs.histogram("serve.compile_s", "bucket-executable build seconds",
                      lo=1e-5, hi=1e3).observe(time.perf_counter() - t0)
        return fn

    def _build_stream_executable(self, prep: PreparedEstimator, tier: str):
        """Bucket executable for a streaming estimator: fn(yp, n_real, snap).

        Unlike the static path, no train tensor is closed over — each call
        reads the snapshot its dispatch is pinned to.  Normalization uses
        the snapshot's live count (appends/evictions move it), and the
        prune decision re-resolves per call because the live count drifts
        across the auto threshold as points come and go.
        """
        cfg = prep.config
        laplace = cfg.method == "laplace"

        if cfg.backend == "pallas":
            from repro.kernels import ops

            jfn = jax.jit(lambda yp, xt, nrm_x, xt_lo: ops.flash_kde_prepared(
                yp, xt, nrm_x, prep.h, xt_lo,
                precision=tier,
                block_m=prep.block_m, block_n=prep.block_n,
                interpret=cfg.interpret, laplace=laplace,
            ))

            def fn(yp, n_real, snap):
                cols = prep.stream.columns_for(tier, snap)
                eps = ops.resolve_prune(cfg.prune, snap.n_live,
                                        prep.block_n)
                if eps is not None and cols.meta is not None:
                    sums = ops.flash_kde_prepared(
                        yp, cols.xt, cols.nrm_x, prep.h, cols.xt_lo,
                        precision=tier,
                        block_m=prep.block_m, block_n=prep.block_n,
                        interpret=cfg.interpret, laplace=laplace,
                        prune=cfg.prune, columns=cols, n_real=n_real,
                    )
                else:
                    sums = jfn(yp, cols.xt, cols.nrm_x, cols.xt_lo)
                return sums / snap.norm

            return fn

        from repro.core import kde as ref

        eval_fn = ref.laplace_kde_eval if laplace else ref.kde_eval
        jfn = jax.jit(
            lambda yp, pts: eval_fn(pts, yp, prep.h, block=cfg.block)
        )
        # snap.xp is the live set padded to a pow2 row bucket (bounded
        # retraces across generations); sentinel rows contribute exactly
        # 0.0 to the sums but inflate eval_fn's 1/n normalization, so
        # rescale padded-n back to the live count
        return lambda yp, n_real, snap: jfn(yp, snap.xp) * (
            snap.xp.shape[0] / snap.n_live
        )

    def _build_executable(self, prep: PreparedEstimator, tier: str):
        """Bucket executable: padded (bucket, d) queries → (bucket,) dens.

        The executable signature is ``fn(yp, n_real)`` — ``n_real`` is the
        true (pre-padding) query count; the pruned pallas path needs it to
        keep sentinel rows out of the row-tile geometry, every other
        backend ignores it.

        Each executable owns its jit wrapper (train tensors passed as
        arguments, not baked as constants), so evicting an entry from the
        LRU releases its compiled program — the cache bounds compilations,
        not just Python closures.
        """
        cfg = prep.config
        laplace = cfg.method == "laplace"

        if cfg.backend == "pallas":
            from repro.kernels import ops

            cols = prep.columns_for(tier)
            # decide pruning ONCE per executable: "auto" below the size
            # threshold means every request takes the plain jitted dense
            # path — no per-request python dispatch overhead
            eps = ops.resolve_prune(cfg.prune, prep.n_true, prep.block_n)
            if eps is not None and cols.meta is not None:
                # Pruned path: not a single jit program — the per-batch
                # bounds prepass host-syncs to compact visit lists, and
                # flash_kde_prepared jit-caches the kernel per bucketed
                # visit extent underneath.
                def pruned_fn(yp, n_real):
                    sums = ops.flash_kde_prepared(
                        yp, cols.xt, cols.nrm_x, prep.h, cols.xt_lo,
                        precision=tier,
                        block_m=prep.block_m, block_n=prep.block_n,
                        interpret=cfg.interpret, laplace=laplace,
                        prune=cfg.prune, columns=cols, n_real=n_real,
                    )
                    return sums / prep.norm

                return pruned_fn
            jfn = jax.jit(lambda yp, xt, nrm_x, xt_lo: ops.flash_kde_prepared(
                yp, xt, nrm_x, prep.h, xt_lo,
                precision=tier,
                block_m=prep.block_m, block_n=prep.block_n,
                interpret=cfg.interpret, laplace=laplace,
            ) / prep.norm)
            return lambda yp, n_real: jfn(yp, cols.xt, cols.nrm_x,
                                          cols.xt_lo)

        if cfg.backend == "ring":
            from repro.distributed import ring

            eval_fn = ring.ring_laplace_kde if laplace else ring.ring_kde
            jfn = jax.jit(lambda yp, xs: eval_fn(
                xs, yp, prep.h, n_true=prep.n_true, mesh=prep.mesh,
            ))
            return lambda yp, n_real: jfn(yp, prep.x_sharded)

        from repro.core import kde as ref

        eval_fn = ref.laplace_kde_eval if laplace else ref.kde_eval
        jfn = jax.jit(
            lambda yp, pts: eval_fn(pts, yp, prep.h, block=cfg.block)
        )
        return lambda yp, n_real: jfn(yp, prep.points)


__all__ = ["ServeEngine"]
