"""Multi-device SD-KDE: the 2-D ring decomposition on a host-device mesh.

Runs the SAME program the flash_sdkde_* dry-run cells lower at 256/512
chips, on 8 forced host devices, and checks it against the single-device
reference — the scaled-down multi-pod demonstration.

    PYTHONPATH=src python examples/distributed_sdkde.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import AxisType  # noqa: E402

from repro.core import kde as ref  # noqa: E402
from repro.distributed.ring2d import pad_for_mesh, ring2d_sdkde  # noqa: E402
from repro.core.mixtures import benchmark_mixture_16d  # noqa: E402


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")

    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(0)
    x = mix.sample(key, 16384)
    y = mix.sample(jax.random.fold_in(key, 1), 2048)
    h = 0.5

    x = pad_for_mesh(x, mesh)
    fn = jax.jit(lambda a, b: ring2d_sdkde(a, b, h, mesh=mesh, chunk=512))
    t0 = time.time()
    p = np.asarray(fn(x, y))
    t_ring = time.time() - t0

    p_ref = np.asarray(ref.sdkde_eval(x, y, h, block=2048))
    np.testing.assert_allclose(p, p_ref, rtol=3e-4)
    print(f"ring2d SD-KDE on 16k points x 2k queries: {t_ring*1e3:.0f}ms "
          f"(incl. compile), max rel err "
          f"{float(np.max(np.abs(p - p_ref) / np.abs(p_ref))):.2e}")
    print("distributed == single-device: OK")


if __name__ == "__main__":
    main()
