"""SD-KDE density weighting as a data-pipeline stage (DESIGN.md §4).

The paper's estimator applied to the framework's data layer: score a corpus
of example embeddings with Flash-SD-KDE, up-weight low-density tail
examples, and show the re-weighted sampler visits the tail ~uniformly.

    PYTHONPATH=src python examples/density_weighted_data.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.estimator import EstimatorConfig
from repro.data.density import DensityWeighting


def main():
    key = jax.random.PRNGKey(0)
    # Corpus: 95% near-duplicate cluster + 5% rare tail (the real-world
    # shape density weighting exists for).
    dup = jax.random.normal(key, (1900, 16)) * 0.05
    tail = jax.random.normal(jax.random.fold_in(key, 1), (100, 16)) * 2 + 4
    corpus = jnp.concatenate([dup, tail])

    stage = DensityWeighting(alpha=0.75,
                             config=EstimatorConfig(block=512)).fit(corpus)
    w = stage(corpus)
    print(f"mean weight: duplicates={float(w[:1900].mean()):.3f}  "
          f"tail={float(w[1900:].mean()):.3f}  "
          f"(ratio {float(w[1900:].mean()/w[:1900].mean()):.1f}x)")

    # Resample a batch with the weights: tail representation jumps from
    # 5% to a much healthier fraction.
    idx = stage.resample_indices(corpus, jax.random.PRNGKey(2), 256)
    frac_tail = float((np.asarray(idx) >= 1900).mean())
    print(f"tail fraction: raw 5.0%  ->  resampled {100*frac_tail:.1f}%")
    assert frac_tail > 0.15
    print("OK")


if __name__ == "__main__":
    main()
