"""Serving quickstart: one debias pass, many cheap query batches.

    PYTHONPATH=src python examples/serve_queries.py
"""

import jax

from repro.core.mixtures import mixture_for_dim
from repro.serve import QueryRequest, ServeConfig, ServeEngine


def main():
    mix = mixture_for_dim(8)
    key = jax.random.PRNGKey(0)

    # One engine can serve many datasets; each is debiased exactly once.
    eng = ServeEngine(ServeConfig(backend="pallas", method="sdkde",
                                  interpret=True, block_m=32, block_n=256,
                                  min_batch=32, max_batch=256))
    eng.register("tenant-a", mix.sample(key, 2048))
    eng.register("tenant-b", mix.sample(jax.random.fold_in(key, 1), 1024))
    print(f"registered {eng.registry.keys()}  "
          f"(debias passes run: {eng.registry.n_fits})")

    # Ragged single requests: padded to shape buckets, no recompile storms.
    pool = mix.sample(jax.random.fold_in(key, 2), 512)
    for m in (3, 40, 170, 40, 3):
        dens = eng.query(QueryRequest(key="tenant-a",
                                      points=pool[:m])).value
        print(f"query m={m:4d} -> bucket exec, density[0]={float(dens[0]):.3e}")

    # Micro-batching: coalesce concurrent requests into ONE dispatch.
    outs = [a.value for a in eng.query_many(
        [QueryRequest(key="tenant-b", points=q)
         for q in (pool[:5], pool[5:90], pool[90:101])])]
    print(f"coalesced 3 requests -> shapes {[tuple(o.shape) for o in outs]}")

    s = eng.latency.summary()
    print(f"latency: p50={s.p50_ms:.2f}ms p99={s.p99_ms:.2f}ms "
          f"({s.queries} queries)")
    print(f"bucket cache: {eng.cache.hits} hits / {eng.cache.misses} misses")

    # Re-registering is free — the registry is idempotent per key.
    eng.register("tenant-a", mix.sample(key, 2048))
    print(f"after re-register: debias passes still {eng.registry.n_fits}")


if __name__ == "__main__":
    main()
