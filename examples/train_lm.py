"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the SAME train-step program the multi-pod dry-run lowers (pjit +
scan-over-layers + grad accumulation + AdamW/ZeRO), at a ~100M config on
CPU, with async checkpointing and restart.  Loss must drop substantially
from its ln(vocab) starting point.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ShapeCfg, get_arch
from repro.launch.steps import make_train_step
from repro.launch.train import shaped_batch
from repro.distributed.elastic import make_mesh, plan_mesh
from repro.models.common import init_params, param_count
from repro.optim.adamw import adamw_init


def hundred_m_config():
    """~100M-param gemma2-family config (reduced depth/width, real vocab)."""
    base = get_arch("gemma2_2b").model
    return dataclasses.replace(
        base, n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32768,
        dtype=jnp.float32, param_dtype=jnp.float32,
        remat="none", loss_chunk=128, sliding_window=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    arch = dataclasses.replace(get_arch("gemma2_2b"), model=cfg)
    print(f"params: {param_count(cfg)/1e6:.1f}M")

    mesh = make_mesh(plan_mesh(len(jax.devices()), model_parallel=1))
    shape = ShapeCfg("train", "train", args.seq, args.global_batch,
                     microbatches=2)
    step_fn, _, donate = make_train_step(
        arch, mesh, shape, peak_lr=3e-3, warmup=20,
        total_steps=max(args.steps, 100),
    )
    jitted = jax.jit(step_fn, donate_argnums=donate)

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = shaped_batch(cfg, 0, step, shape)
        params, opt, metrics = jitted(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if step % 25 == 0 or step == args.steps - 1:
            tps = args.global_batch * args.seq * (step + 1) / (
                time.time() - t0)
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"({tps:.0f} tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, {"params": params})
    ckpt.wait()
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"(start ln(V)={np.log(cfg.vocab_size):.2f})")
    assert last < first - 1.0, "training did not converge"
    print("OK")


if __name__ == "__main__":
    main()
