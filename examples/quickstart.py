"""Quickstart: fit and evaluate Flash-SD-KDE in ten lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.estimator import SDKDE, KDE, LaplaceKDE, EstimatorConfig
from repro.core.metrics import oracle_errors
from repro.core.mixtures import benchmark_mixture_16d


def main():
    mix = benchmark_mixture_16d()
    key = jax.random.PRNGKey(0)
    x_train = mix.sample(key, 8192)                    # 16-D mixture samples
    x_query = mix.sample(jax.random.fold_in(key, 1), 1024)

    # --- the paper's estimator, default (streaming-GEMM) backend ---------
    sdkde = SDKDE().fit(x_train)                       # score pass + shift
    density = sdkde.evaluate(x_query)                  # KDE on debiased pts
    print(f"SD-KDE: h={float(sdkde.h):.4f}  "
          f"density[:4]={[f'{v:.3e}' for v in density[:4]]}")

    # --- same API, Pallas kernel backend (interpret=True on CPU) ---------
    flash = SDKDE(config=EstimatorConfig(backend="pallas", block_m=128,
                                         block_n=512)).fit(x_train[:2048])
    print(f"Pallas backend density[0]={float(flash(x_query[:8])[0]):.3e}")

    # --- accuracy vs the oracle: SD-KDE beats classical KDE --------------
    h = float(sdkde.h)
    for name, est in [("kde", KDE(h)), ("sdkde", SDKDE(h)),
                      ("laplace", LaplaceKDE(h))]:
        est.fit(x_train)
        e = oracle_errors(lambda g: est.evaluate(g), mix, key, n_mc=2048)
        print(f"{name:8s} MISE={e.mise:.3e} MIAE={e.miae:.3e} "
              f"neg_mass={e.neg_mass:.2e}")


if __name__ == "__main__":
    main()
